//! Pool control plane: the header carved out of the front of a file-backed
//! pool's doorbell region, through which independent OS processes
//! rendezvous into one communicator world.
//!
//! This is the NCCL-unique-id bootstrap transplanted onto the paper's
//! substrate: instead of exchanging an id out of band, every process maps
//! the same DAX-style file (§2.2, Listing 1) and the *pool itself* is the
//! rendezvous channel. Rank 0 initializes the header — magic, protocol
//! version, a layout fingerprint, a generation stamp — then every rank
//! registers in its per-rank slot and bumps the atomic arrival counter;
//! construction completes when all `world_size` ranks have arrived.
//!
//! Safety rails:
//! - **magic/version/layout-hash**: a joiner mapping a foreign file, or a
//!   pool created for a different topology, fails with a clear error
//!   instead of exchanging garbage;
//! - **generation stamp**: every re-initialization bumps it, and all
//!   control waits (rendezvous, barriers, launch epochs) recheck it — a
//!   stale mapper from a previous world fails fast instead of hanging;
//! - **per-rank join words**: a duplicate `--rank` is detected instead of
//!   corrupting the arrival count;
//! - **liveness leases + alive mask** (v10): every member stamps a
//!   monotonic heartbeat word on its launch path, the header carries an
//!   alive-rank bitmask plus shrink bookkeeping, and a
//!   [`WorldHealth`] probe classifies each rank live/suspect/dead — the
//!   substrate for the elastic shrink/regrow protocol
//!   (`ProcessGroup::shrink`).
//!
//! Region layout (64 B doorbell slots, one u32 word per concern):
//!
//! ```text
//! slot 0..8    header: magic, version, layout-hash lo/hi, generation,
//!              arrivals, world-size, elastic words (alive-mask lo/hi,
//!              shrink count, last-declared-dead rank)
//! slot 8..64   per-rank slots: join count, split color, split key,
//!              liveness lease (monotonic heartbeat)
//! slot 64..    group windows; each group's first 64 slots are its launch
//!              control — an in-flight ring of up to [`MAX_PIPELINE_DEPTH`]
//!              epoch slices (per-slice launch barrier, stream barrier, and
//!              epoch word) plus the whole-group barrier — the rest are
//!              plan doorbells, carved into N epoch slices for pipelined
//!              launches (the configured ring depth N is part of the
//!              layout hash, so mixed-depth mappers fail fast)
//! top          optional KV-cache reserve (v7): the last `kv_slots` slots
//!              of the region hold the [`crate::kvcache`] page arena +
//!              publication records, excluded from every plan window above
//!              (the reserve size is part of the layout hash)
//! ```

use crate::doorbell::DOORBELL_SLOT;
use crate::pool::ShmPool;
use crate::topology::ClusterSpec;
use crate::util::fnv1a64;
use anyhow::{bail, ensure, Result};
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// "CCLP" — marks an initialized pool control plane.
pub const POOL_MAGIC: u32 = 0x4343_4C50;
/// Bumped with every incompatible control-plane change. v5: the group
/// control prefix grew from two epoch halves to an N-deep ring of up to
/// [`MAX_PIPELINE_DEPTH`] epoch slices (per-slice launch/stream barriers +
/// a wrapping epoch-word ring), and the layout hash covers the configured
/// ring depth. v6: the layout hash additionally covers the tuner algorithm
/// version, so builds whose `CclConfig::auto()` resolution could diverge
/// fail rendezvous instead of desyncing mid-launch. v7: an optional
/// KV-cache reserve ([`crate::kvcache`]) is carved from the *top* of the
/// doorbell region and excluded from the group's plan window; the reserve
/// size joins the layout hash, since mappers configured with different
/// reserves would carve different plan windows. v9 (proto 8): the layout
/// hash covers the multi-pool topology fingerprint. v10 (proto 9): the
/// header's reserved slot 7 became the elastic words (alive-rank mask,
/// shrink count, last-declared-dead rank), each per-rank slot gained a
/// liveness-lease heartbeat word, and each group control prefix gained a
/// dedicated shrink-round barrier (words 50/51) — a v9 mapper would
/// neither stamp leases nor honor a shrink round, so the protocols must
/// not mix.
pub const POOL_PROTO_VERSION: u32 = 9;
/// Header slots at the very base of the doorbell region.
pub const HEADER_SLOTS: usize = 8;
/// One rendezvous slot per global rank.
pub const MAX_POOL_WORLD: usize = 56;
/// Total slots reserved for the control plane (header + rank slots).
pub const CTRL_SLOTS: usize = HEADER_SLOTS + MAX_POOL_WORLD;
/// Deepest epoch ring the fixed-size group control prefix can hold. Pool
/// bootstraps reject deeper configured depths up front; thread-local
/// groups are not bound by it (their launch sync never touches these
/// words).
pub const MAX_PIPELINE_DEPTH: usize = 8;
/// Control slots at the front of every group's doorbell window (v5: up to
/// [`MAX_PIPELINE_DEPTH`] epoch slices × [`GC_SLICE_WORDS`] words, the
/// whole-group barrier, and reserved headroom).
pub const GROUP_CTRL_SLOTS: usize = 64;

// Header word slot indices.
const W_MAGIC: usize = 0;
const W_VERSION: usize = 1;
const W_LAYOUT_LO: usize = 2;
const W_LAYOUT_HI: usize = 3;
const W_GENERATION: usize = 4;
const W_ARRIVALS: usize = 5;
const W_WORLD: usize = 6;
/// The elastic words live together in header slot 7 (v10).
const W_ELASTIC: usize = 7;

// Byte offsets of the elastic words within the [`W_ELASTIC`] slot.
/// Alive-rank bitmask, low 32 ranks (bit `r` set = rank `r` is a member
/// in good standing; cleared by [`PoolControl::publish_shrink`]).
const E_ALIVE_LO: usize = 0;
/// Alive-rank bitmask, ranks 32..[`MAX_POOL_WORLD`].
const E_ALIVE_HI: usize = 4;
/// Number of shrink rounds published against this world since its last
/// (re-)initialization. Nonzero distinguishes a `WorldShrunk` generation
/// bump from a plain re-initialization.
const E_SHRINK: usize = 8;
/// Global rank most recently declared dead, **plus one** (0 = none yet).
const E_DEAD: usize = 12;

// Byte offsets of the words within a per-rank slot.
const R_JOINS: usize = 0;
const R_COLOR: usize = 4;
const R_KEY: usize = 8;
/// Liveness lease: a monotonic (wrapping) heartbeat the rank's launch and
/// barrier paths stamp; see [`lease_progressed`] for the wrap discipline.
const R_LEASE: usize = 12;

// Word indices within a group's control prefix (each in its own slot).
//
// The prefix is an in-flight ring of N *epoch slices* (N = the group's
// configured pipeline depth, at most [`MAX_PIPELINE_DEPTH`]): launch `seq`
// of a group runs entirely on slice `seq % N` — its own launch barrier,
// its own stream barrier (for the plans' `Op::Barrier`), and its own epoch
// word — so up to N launches' publications and retrievals proceed on
// disjoint slices concurrently. Words 48/49 are the whole-group barrier
// backing `ProcessGroup::barrier()` and the `split()` rounds, which must
// be independent of every slice.
/// Per-slice launch-barrier arrival counter.
pub const GC_LAUNCH_CNT: usize = 0;
/// Per-slice launch-barrier sense word.
pub const GC_LAUNCH_SENSE: usize = 1;
/// Per-slice stream-barrier arrival counter (backs the plans' `Op::Barrier`).
pub const GC_STREAM_CNT: usize = 2;
/// Per-slice stream-barrier sense word.
pub const GC_STREAM_SENSE: usize = 3;
/// Per-slice epoch word (the launch-sequence publication).
pub const GC_EPOCH: usize = 4;
/// Stride between consecutive slices' word blocks (5 words + 1 reserved).
pub const GC_SLICE_WORDS: usize = 6;
/// Whole-group barrier arrival counter (slice-independent).
pub const GC_GROUP_CNT: usize = MAX_PIPELINE_DEPTH * GC_SLICE_WORDS;
/// Whole-group barrier sense word.
pub const GC_GROUP_SENSE: usize = GC_GROUP_CNT + 1;
/// Shrink-round barrier arrival counter (v10). The shrink protocol may
/// not reuse the whole-group barrier: the member being declared dead may
/// have died mid-`barrier()`, leaving words 48/49 torn, so survivors meet
/// on this dedicated pair — untouched by normal operation — and the
/// leader wipes everything *below* it while the others are parked here.
pub const GC_SHRINK_CNT: usize = GC_GROUP_SENSE + 1;
/// Shrink-round barrier sense word (v10).
pub const GC_SHRINK_SENSE: usize = GC_SHRINK_CNT + 1;

/// Byte offset of group-control word `word` for a group whose doorbell
/// window starts at absolute slot `window_base_slot`.
pub(crate) fn group_word_off(window_base_slot: usize, word: usize) -> usize {
    (window_base_slot + word) * DOORBELL_SLOT
}

/// Word index of per-slice control word `word` for epoch slice `slice`.
pub fn slice_word(slice: usize, word: usize) -> usize {
    debug_assert!(slice < MAX_PIPELINE_DEPTH && word < GC_SLICE_WORDS);
    slice * GC_SLICE_WORDS + word
}

/// The group control-word map, exposed for the static analyzer: absolute
/// doorbell-slot index of every *live* control word of a group whose
/// control prefix starts at `prefix_base_slot` and whose epoch ring is
/// `depth` slices deep. Plan windows (and every epoch slice carved from
/// them) must never cover any of these slots — the
/// [`crate::analysis`] ring checks take this list as their `ctrl_slots`.
pub fn control_word_slots(prefix_base_slot: usize, depth: usize) -> Vec<usize> {
    let mut slots = Vec::with_capacity(depth.min(MAX_PIPELINE_DEPTH) * 5 + 4);
    for slice in 0..depth.min(MAX_PIPELINE_DEPTH) {
        for word in [GC_LAUNCH_CNT, GC_LAUNCH_SENSE, GC_STREAM_CNT, GC_STREAM_SENSE, GC_EPOCH] {
            slots.push(prefix_base_slot + slice_word(slice, word));
        }
    }
    slots.push(prefix_base_slot + GC_GROUP_CNT);
    slots.push(prefix_base_slot + GC_GROUP_SENSE);
    slots.push(prefix_base_slot + GC_SHRINK_CNT);
    slots.push(prefix_base_slot + GC_SHRINK_SENSE);
    slots
}

/// The elastic word map (v10), exposed for the static analyzer: absolute
/// doorbell-slot index of the alive-mask/shrink-record slot and of every
/// possible liveness-lease slot. All of them live below [`CTRL_SLOTS`] —
/// [`crate::analysis::check_elastic_words`] asserts that, and that no
/// group window or KV reserve ever reaches one (a plan doorbell landing
/// on a lease word would fake a heartbeat for a dead rank).
pub fn elastic_word_slots() -> Vec<usize> {
    let mut slots = Vec::with_capacity(1 + MAX_POOL_WORLD);
    slots.push(W_ELASTIC);
    slots.extend(HEADER_SLOTS..HEADER_SLOTS + MAX_POOL_WORLD);
    slots
}

/// The epoch word published on a slice for launch `seq`: the
/// wrapping-truncated **global** launch sequence plus one (so the very
/// first launch, `seq = 0`, publishes a value distinct from the
/// zero-initialized word).
///
/// Keying the word off the global sequence — not a per-slice launch count —
/// is what makes the ring wrap-robust at every depth: consecutive launches
/// on one slice are exactly N apart in `seq` in steady state, and between
/// 1 and `2N − 1` apart around the u64 sequence wrap when the ring depth
/// does not divide 2^64 ("slice-index drift": N = 3 runs `u64::MAX` and
/// `0` back-to-back on slice 0 while stretching slice 1's gap to 4). Every
/// gap in `1..=2N-1` stays nonzero under u32 truncation
/// (`2N − 1 < 2^32`), so adjacent same-slice launches always publish
/// distinct words.
pub(crate) fn epoch_word_for(seq: u64) -> u32 {
    (seq as u32).wrapping_add(1)
}

/// Byte offset of the header's generation word (the stale-mapper guard).
pub fn generation_offset() -> usize {
    W_GENERATION * DOORBELL_SLOT
}

/// Byte offset of global rank `rank`'s liveness-lease word — the launch
/// path stamps it directly (it runs on a background thread that holds no
/// [`PoolControl`] handle).
pub(crate) fn lease_offset(rank: usize) -> usize {
    (HEADER_SLOTS + rank) * DOORBELL_SLOT + R_LEASE
}

/// Byte offset of elastic word `byte` within the [`W_ELASTIC`] header slot.
fn elastic_offset(byte: usize) -> usize {
    W_ELASTIC * DOORBELL_SLOT + byte
}

/// Wrapping distance from lease observation `prev` to `cur`. The lease is
/// a u32 that only ever increments, so the forward gap is well defined
/// modulo 2^32.
pub fn lease_gap(prev: u32, cur: u32) -> u32 {
    cur.wrapping_sub(prev)
}

/// Whether a rank made heartbeat progress between two lease observations —
/// the wrap discipline mirroring the epoch words' (v5): any *forward* gap
/// in `1..2^31` counts, including across the u32 wrap itself
/// (`prev = u32::MAX, cur = 0` is one beat forward). A gap of 0 is
/// silence; gaps of `2^31` and beyond are treated as silence too rather
/// than risk reading a half-observed word as progress — a live rank would
/// need 2^31 heartbeats between two probes to be misjudged, which no
/// probe cadence allows.
pub fn lease_progressed(prev: u32, cur: u32) -> bool {
    let gap = lease_gap(prev, cur);
    gap != 0 && gap < 1 << 31
}

/// Typed error surfaced when the control plane's generation moved because
/// survivors ran the shrink protocol (as opposed to a plain
/// re-initialization): every in-flight or subsequent operation on the old
/// world fails fast with this instead of hanging. Downcast from the
/// `anyhow` chain on control-plane call sites; pipelined futures surface
/// it in their error *message* (launch outcomes cross a thread boundary
/// as strings).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorldShrunk {
    /// Generation this handle joined at.
    pub joined_generation: u32,
    /// Generation the shrink round published.
    pub current_generation: u32,
    /// Global rank most recently declared dead (`None` if the word was
    /// unreadable).
    pub dead_rank: Option<usize>,
}

impl std::fmt::Display for WorldShrunk {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "world shrunk (generation {} -> {}",
            self.joined_generation, self.current_generation
        )?;
        if let Some(r) = self.dead_rank {
            write!(f, "; rank {r} declared dead")?;
        }
        write!(
            f,
            "): in-flight collectives on the old world cannot complete — continue on \
             the shrunk group returned by shrink(), or rejoin at the next generation"
        )
    }
}

impl std::error::Error for WorldShrunk {}

/// The error for a generation mismatch, typed by *why* the generation
/// moved: a published shrink round yields [`WorldShrunk`]; anything else
/// is the classic stale-mapper re-initialization message. Shared by every
/// generation guard (rendezvous-side checks and the launch threads).
pub(crate) fn generation_error(pool: &ShmPool, joined: u32, cur: u32) -> anyhow::Error {
    pool.flush(W_ELASTIC * DOORBELL_SLOT, DOORBELL_SLOT);
    let read = |byte: usize| {
        pool.atomic_u32(elastic_offset(byte))
            .map(|w| w.load(Ordering::Acquire))
            .unwrap_or(0)
    };
    if read(E_SHRINK) != 0 {
        let dead = read(E_DEAD);
        return anyhow::Error::new(WorldShrunk {
            joined_generation: joined,
            current_generation: cur,
            dead_rank: (dead != 0).then(|| dead as usize - 1),
        });
    }
    anyhow::anyhow!(
        "pool control plane re-initialized (generation {cur}, joined at {joined}): \
         stale mapper must re-bootstrap"
    )
}

/// If the generation moved since `joined`, the typed reason; `None` while
/// the world is still the one we joined.
pub(crate) fn stale_generation_error(pool: &ShmPool, joined: u32) -> Option<anyhow::Error> {
    let cur = pool.atomic_u32(generation_offset()).ok()?.load(Ordering::Acquire);
    (cur != joined).then(|| generation_error(pool, joined, cur))
}

/// One rank's liveness classification (see [`LeaseMonitor`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RankHealth {
    /// Alive-mask bit set and the lease progressed recently.
    Live,
    /// No lease progress for at least half the configured timeout.
    Suspect,
    /// No lease progress for the full timeout, or the alive-mask bit was
    /// cleared by a shrink round.
    Dead,
}

/// A `ProcessGroup::probe_health` snapshot: one [`RankHealth`] per group
/// rank (index = group rank, not global rank).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorldHealth {
    pub ranks: Vec<RankHealth>,
}

impl WorldHealth {
    pub fn all_live(&self) -> bool {
        self.ranks.iter().all(|r| *r == RankHealth::Live)
    }

    /// Group ranks classified dead.
    pub fn dead(&self) -> Vec<usize> {
        self.ranks
            .iter()
            .enumerate()
            .filter(|(_, r)| **r == RankHealth::Dead)
            .map(|(i, _)| i)
            .collect()
    }

    /// Group ranks classified suspect (stalled but not yet past timeout).
    pub fn suspects(&self) -> Vec<usize> {
        self.ranks
            .iter()
            .enumerate()
            .filter(|(_, r)| **r == RankHealth::Suspect)
            .map(|(i, _)| i)
            .collect()
    }
}

impl std::fmt::Display for WorldHealth {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for (i, r) in self.ranks.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            let s = match r {
                RankHealth::Live => "live",
                RankHealth::Suspect => "suspect",
                RankHealth::Dead => "dead",
            };
            write!(f, "rank {i} {s}")?;
        }
        Ok(())
    }
}

/// Lease-observation state for one prober: remembers each rank's last
/// observed lease word and when it last *progressed*, and classifies
/// silence against the configured timeout (suspect at half, dead at
/// full). Heartbeats are stamped by the launch/barrier paths, so the
/// monitor is meaningful while the group is actively launching — an idle
/// world goes quiet without being dead, which is why death detection is a
/// probe the caller drives, never an automatic reaper.
pub struct LeaseMonitor {
    last: Vec<(u32, Instant)>,
    suspect_after: Duration,
    dead_after: Duration,
}

impl LeaseMonitor {
    pub(crate) fn new(nranks: usize, timeout: Duration) -> Self {
        let now = Instant::now();
        LeaseMonitor {
            last: vec![(0, now); nranks],
            suspect_after: timeout / 2,
            dead_after: timeout,
        }
    }

    /// The configured full (death) timeout.
    pub fn timeout(&self) -> Duration {
        self.dead_after
    }

    pub(crate) fn classify(&mut self, idx: usize, lease: u32, alive: bool) -> RankHealth {
        if !alive {
            return RankHealth::Dead;
        }
        let now = Instant::now();
        let (prev, since) = &mut self.last[idx];
        if lease_progressed(*prev, lease) {
            *prev = lease;
            *since = now;
            return RankHealth::Live;
        }
        let idle = now.duration_since(*since);
        if idle >= self.dead_after {
            RankHealth::Dead
        } else if idle >= self.suspect_after {
            RankHealth::Suspect
        } else {
            RankHealth::Live
        }
    }
}

const POLL: Duration = Duration::from_millis(2);

/// A joined view of the pool control plane.
pub(crate) struct PoolControl {
    pool: Arc<ShmPool>,
    /// The generation this process joined; all waits recheck it.
    pub(crate) generation: u32,
}

impl Clone for PoolControl {
    /// Subgroups share the parent's joined view (same generation).
    fn clone(&self) -> Self {
        Self {
            pool: Arc::clone(&self.pool),
            generation: self.generation,
        }
    }
}

impl PoolControl {
    fn header(&self, slot: usize) -> Result<&AtomicU32> {
        self.pool.atomic_u32(slot * DOORBELL_SLOT)
    }

    fn rank_word(&self, rank: usize, byte: usize) -> Result<&AtomicU32> {
        self.pool.atomic_u32((HEADER_SLOTS + rank) * DOORBELL_SLOT + byte)
    }

    fn elastic(&self, byte: usize) -> Result<&AtomicU32> {
        self.pool.atomic_u32(elastic_offset(byte))
    }

    /// Fingerprint of everything two mappers must agree on before they may
    /// exchange a single byte through the pool. Since v5 that includes the
    /// configured pipeline ring depth: slice windows and the `seq % N`
    /// slice assignment are pure functions of it, so mappers configured
    /// with different depths would desync silently — the hash makes them
    /// fail fast instead. Since v6 it also covers
    /// [`TUNER_ALGO_VERSION`](crate::collectives::tuner::TUNER_ALGO_VERSION):
    /// `CclConfig::auto()` resolves per rank through the tuner, so two
    /// builds whose tuners could pick different plans for the same spec
    /// must never rendezvous. Since v7 it covers the KV-cache reserve
    /// (`kv_slots`, 0 without one): the reserve is carved from the top of
    /// the doorbell region *before* the plan window, so mappers configured
    /// with different reserves would carve different plan windows — and
    /// different epoch slices — silently. Since v9 it covers the
    /// multi-pool topology fingerprint
    /// ([`PoolSet::fingerprint`](crate::fabric::PoolSet::fingerprint), 0
    /// for flat worlds): a mapper that believes this pool is pool 1 of a
    /// 2×4 fabric and one that believes it is flat — or pool 0 of a 4×2
    /// fabric — would stage different two-level plans over the same
    /// bytes, so they must never rendezvous.
    pub(crate) fn layout_hash(
        spec: &ClusterSpec,
        pool_len: usize,
        ring_depth: usize,
        kv_slots: usize,
        pool_fingerprint: u64,
    ) -> u64 {
        let mut buf = [0u8; 80];
        for (i, v) in [
            spec.nranks as u64,
            spec.ndevices as u64,
            spec.device_capacity as u64,
            spec.db_region_size as u64,
            pool_len as u64,
            POOL_PROTO_VERSION as u64,
            ring_depth as u64,
            crate::collectives::tuner::TUNER_ALGO_VERSION,
            kv_slots as u64,
            pool_fingerprint,
        ]
        .into_iter()
        .enumerate()
        {
            buf[i * 8..(i + 1) * 8].copy_from_slice(&v.to_le_bytes());
        }
        fnv1a64(&buf)
    }

    /// Communicator construction **is itself a collective**: rank 0
    /// initializes the header, every rank registers and waits for all
    /// `world` arrivals. Returns the joined control-plane view.
    pub(crate) fn rendezvous(
        pool: Arc<ShmPool>,
        spec: &ClusterSpec,
        rank: usize,
        world: usize,
        ring_depth: usize,
        kv_slots: usize,
        pool_fingerprint: u64,
        timeout: Duration,
    ) -> Result<Self> {
        ensure!(
            world <= MAX_POOL_WORLD,
            "pool bootstrap supports at most {MAX_POOL_WORLD} ranks, got {world}"
        );
        ensure!(rank < world, "rank {rank} out of range ({world} ranks)");
        let hash = Self::layout_hash(spec, pool.len(), ring_depth, kv_slots, pool_fingerprint);
        let mut ctrl = Self { pool, generation: 0 };
        ctrl.generation = if rank == 0 {
            ctrl.initialize(hash, world, spec.db_region_size)?
        } else {
            ctrl.await_header(hash, world, timeout)?
        };
        ctrl.join(rank, world, timeout)?;
        Ok(ctrl)
    }

    /// Rank 0 only: wipe the doorbell region (header, rank slots, every
    /// group's control words and plan doorbells), stamp a fresh generation
    /// and publish the magic last so joiners never observe a half-written
    /// header.
    fn initialize(&self, hash: u64, world: usize, db_region: usize) -> Result<u32> {
        let old_gen = self.header(W_GENERATION)?.load(Ordering::Acquire);
        // Take the magic down first: joiners spin until it reappears.
        self.header(W_MAGIC)?.store(0, Ordering::Release);
        self.pool.flush(0, DOORBELL_SLOT);
        self.pool.zero(0, db_region)?;
        self.pool.flush(0, db_region);
        let gen = old_gen.wrapping_add(1).max(1);
        self.header(W_LAYOUT_LO)?.store(hash as u32, Ordering::Release);
        self.header(W_LAYOUT_HI)?.store((hash >> 32) as u32, Ordering::Release);
        self.header(W_GENERATION)?.store(gen, Ordering::Release);
        self.header(W_WORLD)?.store(world as u32, Ordering::Release);
        self.header(W_VERSION)?.store(POOL_PROTO_VERSION, Ordering::Release);
        // v10: every configured rank starts alive; the shrink words were
        // zeroed with the region, so a later generation bump reads as a
        // re-initialization unless a shrink round sets them.
        let full = if world >= 64 { u64::MAX } else { (1u64 << world) - 1 };
        self.elastic(E_ALIVE_LO)?.store(full as u32, Ordering::Release);
        self.elastic(E_ALIVE_HI)?.store((full >> 32) as u32, Ordering::Release);
        // Publish: everything above is visible before the magic (Release
        // store + the joiner's Acquire load of the magic word).
        self.header(W_MAGIC)?.store(POOL_MAGIC, Ordering::Release);
        self.pool.flush(0, HEADER_SLOTS * DOORBELL_SLOT);
        Ok(gen)
    }

    /// Joiner side: wait for a published header, then verify we mapped the
    /// world we think we did.
    fn await_header(&self, hash: u64, world: usize, timeout: Duration) -> Result<u32> {
        let start = Instant::now();
        let magic = self.header(W_MAGIC)?;
        while magic.load(Ordering::Acquire) != POOL_MAGIC {
            if start.elapsed() > timeout {
                bail!(
                    "pool bootstrap timed out after {timeout:?} waiting for rank 0 to \
                     initialize the control plane (is rank 0 running against this path?)"
                );
            }
            self.pool.flush(0, DOORBELL_SLOT);
            std::thread::sleep(POLL);
        }
        let ver = self.header(W_VERSION)?.load(Ordering::Acquire);
        ensure!(
            ver == POOL_PROTO_VERSION,
            "pool control plane speaks protocol {ver}, this build speaks {POOL_PROTO_VERSION}"
        );
        let lo = self.header(W_LAYOUT_LO)?.load(Ordering::Acquire) as u64;
        let hi = self.header(W_LAYOUT_HI)?.load(Ordering::Acquire) as u64;
        let found = (hi << 32) | lo;
        ensure!(
            found == hash,
            "pool layout hash mismatch (found {found:#018x}, expected {hash:#018x}): the \
             file at this path was created for a different topology — every rank must use \
             identical ranks/devices/capacity/doorbell-region settings"
        );
        let w = self.header(W_WORLD)?.load(Ordering::Acquire) as usize;
        ensure!(
            w == world,
            "pool world-size mismatch: rank 0 registered {w} ranks, this process expects \
             {world}"
        );
        Ok(self.header(W_GENERATION)?.load(Ordering::Acquire))
    }

    /// Register this rank and wait for the full world. Re-joins
    /// transparently when rank 0 re-initializes mid-wait (crash-restart);
    /// a rank slot that is already taken *and* never re-initialized is
    /// reported as a duplicate `--rank`.
    fn join(&mut self, rank: usize, world: usize, timeout: Duration) -> Result<()> {
        let start = Instant::now();
        'rejoin: loop {
            let gen = self.header(W_GENERATION)?.load(Ordering::Acquire);
            self.generation = gen;
            let prev = self.rank_word(rank, R_JOINS)?.fetch_add(1, Ordering::AcqRel);
            if prev != 0 {
                // Taken: either a duplicate rank in a live world, or the
                // residue of a finished/crashed world rank 0 has not wiped
                // yet. Wait for a re-initialization, then rejoin.
                loop {
                    if self.header(W_GENERATION)?.load(Ordering::Acquire) != gen {
                        continue 'rejoin;
                    }
                    if start.elapsed() > timeout {
                        bail!(
                            "rank {rank} is already registered in this pool world \
                             (join count {}): duplicate --rank, or a stale pool file \
                             rank 0 never re-initialized — remove the file or restart \
                             rank 0",
                            prev + 1
                        );
                    }
                    std::thread::sleep(POLL);
                }
            }
            self.header(W_ARRIVALS)?.fetch_add(1, Ordering::AcqRel);
            self.pool.flush(0, CTRL_SLOTS * DOORBELL_SLOT);
            loop {
                if self.header(W_GENERATION)?.load(Ordering::Acquire) != gen {
                    // Rank 0 restarted underneath us; our registration was
                    // wiped. Rejoin under the new generation. (A lost
                    // arrival increment from the old generation can only
                    // make `arrivals` overshoot, never undershoot — the
                    // counter is a liveness gate, the launch barrier is the
                    // actual synchronization point.)
                    continue 'rejoin;
                }
                let a = self.header(W_ARRIVALS)?.load(Ordering::Acquire) as usize;
                if a >= world {
                    return Ok(());
                }
                if start.elapsed() > timeout {
                    bail!(
                        "pool rendezvous timed out after {timeout:?}: {a}/{world} ranks \
                         arrived (start the missing ranks against the same pool path)"
                    );
                }
                self.pool.flush(0, HEADER_SLOTS * DOORBELL_SLOT);
                std::thread::sleep(POLL);
            }
        }
    }

    /// Fail fast if the control plane's generation moved since we joined —
    /// with the typed reason ([`WorldShrunk`] after a shrink round, the
    /// stale-mapper message after a re-initialization).
    pub(crate) fn check_generation(&self) -> Result<()> {
        let cur = self.header(W_GENERATION)?.load(Ordering::Acquire);
        if cur != self.generation {
            return Err(generation_error(&self.pool, self.generation, cur));
        }
        Ok(())
    }

    /// The generation word as currently published (not the joined one).
    pub(crate) fn current_generation(&self) -> Result<u32> {
        self.pool.flush(generation_offset(), DOORBELL_SLOT);
        Ok(self.header(W_GENERATION)?.load(Ordering::Acquire))
    }

    /// A view of the same control plane joined at `generation` — what a
    /// shrink round hands the surviving subgroup.
    pub(crate) fn at_generation(&self, generation: u32) -> Self {
        Self {
            pool: Arc::clone(&self.pool),
            generation,
        }
    }

    /// Stamp this rank's liveness lease (wrapping increment + flush).
    pub(crate) fn heartbeat(&self, rank: usize) -> Result<()> {
        self.rank_word(rank, R_LEASE)?.fetch_add(1, Ordering::AcqRel);
        self.pool
            .flush((HEADER_SLOTS + rank) * DOORBELL_SLOT, DOORBELL_SLOT);
        Ok(())
    }

    /// Read a peer's current lease word (flushing first, so a remote
    /// mapper's stores are visible).
    pub(crate) fn read_lease(&self, rank: usize) -> Result<u32> {
        self.pool
            .flush((HEADER_SLOTS + rank) * DOORBELL_SLOT, DOORBELL_SLOT);
        Ok(self.rank_word(rank, R_LEASE)?.load(Ordering::Acquire))
    }

    /// The alive-rank bitmask (bit `r` = global rank `r` in good standing).
    pub(crate) fn alive_mask(&self) -> Result<u64> {
        self.pool.flush(W_ELASTIC * DOORBELL_SLOT, DOORBELL_SLOT);
        let lo = self.elastic(E_ALIVE_LO)?.load(Ordering::Acquire) as u64;
        let hi = self.elastic(E_ALIVE_HI)?.load(Ordering::Acquire) as u64;
        Ok(lo | (hi << 32))
    }

    /// Number of shrink rounds published since the last initialization.
    pub(crate) fn shrink_count(&self) -> Result<u32> {
        self.pool.flush(W_ELASTIC * DOORBELL_SLOT, DOORBELL_SLOT);
        Ok(self.elastic(E_SHRINK)?.load(Ordering::Acquire))
    }

    /// Shrink-round leader only: declare `dead_rank` dead — clear its
    /// alive bit, record it, bump the shrink count, and *then* bump the
    /// generation, so any guard that observes the new generation already
    /// sees the shrink words explaining it. Returns the new generation.
    pub(crate) fn publish_shrink(&self, dead_rank: usize) -> Result<u32> {
        ensure!(
            dead_rank < MAX_POOL_WORLD,
            "rank {dead_rank} out of range ({MAX_POOL_WORLD} max pool ranks)"
        );
        let mask = self.alive_mask()? & !(1u64 << dead_rank);
        self.elastic(E_ALIVE_LO)?.store(mask as u32, Ordering::Release);
        self.elastic(E_ALIVE_HI)?.store((mask >> 32) as u32, Ordering::Release);
        self.elastic(E_DEAD)?.store(dead_rank as u32 + 1, Ordering::Release);
        self.elastic(E_SHRINK)?.fetch_add(1, Ordering::AcqRel);
        self.pool.flush(W_ELASTIC * DOORBELL_SLOT, DOORBELL_SLOT);
        let genw = self.header(W_GENERATION)?;
        let gen = genw.load(Ordering::Acquire).wrapping_add(1).max(1);
        genw.store(gen, Ordering::Release);
        self.pool.flush(generation_offset(), DOORBELL_SLOT);
        Ok(gen)
    }

    /// Publish this rank's `(color, key)` for an in-flight `split()`.
    pub(crate) fn publish_split(&self, rank: usize, color: u32, key: u32) -> Result<()> {
        self.rank_word(rank, R_COLOR)?.store(color, Ordering::Release);
        self.rank_word(rank, R_KEY)?.store(key, Ordering::Release);
        self.pool
            .flush((HEADER_SLOTS + rank) * DOORBELL_SLOT, DOORBELL_SLOT);
        Ok(())
    }

    /// Read a peer's published `(color, key)`.
    pub(crate) fn read_split(&self, rank: usize) -> Result<(u32, u32)> {
        Ok((
            self.rank_word(rank, R_COLOR)?.load(Ordering::Acquire),
            self.rank_word(rank, R_KEY)?.load(Ordering::Acquire),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ClusterSpec {
        let mut s = ClusterSpec::new(2, 6, 1 << 20);
        s.db_region_size = 64 * 128; // 128 slots
        s
    }

    fn pool_for(s: &ClusterSpec) -> Arc<ShmPool> {
        Arc::new(ShmPool::anon(s.ndevices * s.device_capacity).unwrap())
    }

    #[test]
    fn two_ranks_rendezvous_over_one_pool() {
        let s = spec();
        let pool = pool_for(&s);
        let (a, b) = std::thread::scope(|sc| {
            let p0 = Arc::clone(&pool);
            let p1 = Arc::clone(&pool);
            let s0 = s.clone();
            let s1 = s.clone();
            let h0 = sc.spawn(move || {
                PoolControl::rendezvous(p0, &s0, 0, 2, 2, 0, 0, Duration::from_secs(10))
            });
            let h1 = sc.spawn(move || {
                PoolControl::rendezvous(p1, &s1, 1, 2, 2, 0, 0, Duration::from_secs(10))
            });
            (h0.join().unwrap(), h1.join().unwrap())
        });
        let (a, b) = (a.unwrap(), b.unwrap());
        assert_eq!(a.generation, b.generation);
        assert!(a.generation >= 1);
        a.check_generation().unwrap();
        // Split scratch round-trips through the per-rank slots.
        a.publish_split(0, 7, 3).unwrap();
        assert_eq!(b.read_split(0).unwrap(), (7, 3));
    }

    #[test]
    fn layout_hash_mismatch_fails_the_joiner_fast() {
        let s = spec();
        let pool = pool_for(&s);
        // Rank 0 stands up a world for `s`...
        let ctrl = init_header(&pool, &s);
        // ...a joiner that believes in a different topology must be
        // rejected before exchanging anything.
        let mut other = s.clone();
        other.ndevices = 3;
        other.device_capacity = 2 << 20; // same pool size, different shape
        let err = PoolControl::rendezvous(
            Arc::clone(&pool),
            &other,
            1,
            2,
            2,
            0,
            0,
            Duration::from_millis(300),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("layout hash mismatch"), "{err:#}");
        // A joiner configured with a different pipeline ring depth is a
        // layout mismatch too: the `seq % N` slice assignment would desync.
        let err = PoolControl::rendezvous(
            Arc::clone(&pool),
            &s,
            1,
            2,
            3,
            0,
            0,
            Duration::from_millis(300),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("layout hash mismatch"), "{err:#}");
        // So is a different KV-cache reserve: the joiner would carve a
        // different plan window out of the same doorbell region.
        let err = PoolControl::rendezvous(
            Arc::clone(&pool),
            &s,
            1,
            2,
            2,
            128,
            0,
            Duration::from_millis(300),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("layout hash mismatch"), "{err:#}");
        // v9: so is a different multi-pool topology — a mapper that
        // believes this pool is one leg of a 2-pool fabric must never
        // rendezvous with a flat world over the same file.
        let err = PoolControl::rendezvous(
            Arc::clone(&pool),
            &s,
            1,
            2,
            2,
            0,
            crate::fabric::PoolSet::uniform(2, 2).unwrap().fingerprint(),
            Duration::from_millis(300),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("layout hash mismatch"), "{err:#}");
        drop(ctrl);
    }

    /// Initialize a header as rank 0 would, without blocking on the join
    /// (world of 1 is below the ClusterSpec floor, so do it manually).
    fn init_header(pool: &Arc<ShmPool>, s: &ClusterSpec) -> PoolControl {
        let ctrl = PoolControl {
            pool: Arc::clone(pool),
            generation: 0,
        };
        let hash = PoolControl::layout_hash(s, pool.len(), 2, 0, 0);
        let gen = ctrl.initialize(hash, 2, s.db_region_size).unwrap();
        PoolControl {
            pool: Arc::clone(pool),
            generation: gen,
        }
    }

    #[test]
    fn reinitialization_trips_the_generation_guard() {
        let s = spec();
        let pool = pool_for(&s);
        let old = init_header(&pool, &s);
        old.check_generation().unwrap();
        // A second world bootstraps over the same file: the stale handle's
        // next control-plane touch fails fast.
        let _new = init_header(&pool, &s);
        let err = old.check_generation().unwrap_err();
        assert!(format!("{err:#}").contains("re-initialized"), "{err:#}");
    }

    #[test]
    fn duplicate_rank_is_reported() {
        let s = spec();
        let pool = pool_for(&s);
        std::thread::scope(|sc| {
            let p0 = Arc::clone(&pool);
            let p1 = Arc::clone(&pool);
            let p1b = Arc::clone(&pool);
            let s0 = s.clone();
            let s1 = s.clone();
            let s1b = s.clone();
            let h0 = sc.spawn(move || {
                PoolControl::rendezvous(p0, &s0, 0, 2, 2, 0, 0, Duration::from_secs(10))
            });
            let h1 = sc.spawn(move || {
                PoolControl::rendezvous(p1, &s1, 1, 2, 2, 0, 0, Duration::from_secs(10))
            });
            h0.join().unwrap().unwrap();
            h1.join().unwrap().unwrap();
            // World complete; a third process claiming rank 1 again must be
            // told so (short timeout keeps the test fast).
            let err =
                PoolControl::rendezvous(p1b, &s1b, 1, 2, 2, 0, 0, Duration::from_millis(200))
                    .unwrap_err();
            assert!(format!("{err:#}").contains("already registered"), "{err:#}");
        });
    }

    /// The most recent launch before `seq` landing on `seq`'s slice, by
    /// walking the actual issue order backwards — the reference model for
    /// "adjacent same-slice launches" that slice-index drift cannot fool.
    fn prev_same_slice(seq: u64, ring: u64) -> u64 {
        let slice = seq % ring;
        let mut s = seq.wrapping_sub(1);
        loop {
            if s % ring == slice {
                return s;
            }
            s = s.wrapping_sub(1);
        }
    }

    #[test]
    fn epoch_words_wrap_without_ambiguity_at_every_depth() {
        // Fresh slice: the zero-initialized word never equals the first
        // launch's target.
        for seq in 0..8u64 {
            assert_ne!(epoch_word_for(seq), 0);
        }
        // Adjacent same-slice launches always publish distinct words —
        // through the u32 truncation wrap, and through the u64 sequence
        // wrap itself, where rings whose depth does not divide 2^64 drift
        // (N = 3: seq u64::MAX and seq 0 land on slice 0 back-to-back; even
        // depths mask this because they divide 2^64 exactly).
        for ring in [1u64, 2, 3, 4, 5, 8] {
            let probes = [
                0u64,
                1,
                ring,
                u32::MAX as u64,
                (u32::MAX as u64) + 1,
                u64::MAX - 2 * ring,
                u64::MAX - 1,
                u64::MAX,
            ];
            for &seq in &probes {
                for step in 0..2 * ring {
                    let s = seq.wrapping_add(step);
                    let prev = prev_same_slice(s, ring);
                    assert_ne!(
                        epoch_word_for(s),
                        epoch_word_for(prev),
                        "ring {ring}: seq {s} vs its slice predecessor {prev}"
                    );
                }
            }
        }
        // The drift case itself, explicitly: at N = 3 the wrap puts two
        // consecutive launches on slice 0 with distinct words.
        assert_eq!(u64::MAX % 3, 0);
        assert_eq!(0u64 % 3, 0);
        assert_ne!(epoch_word_for(u64::MAX), epoch_word_for(0));
        assert_eq!(epoch_word_for(u64::MAX), 0); // mid-stream zero is fine…
        assert_eq!(epoch_word_for(0), 1); // …its successor moves off it.
    }

    #[test]
    fn slice_words_do_not_collide() {
        let mut seen = std::collections::HashSet::new();
        for s in 0..MAX_PIPELINE_DEPTH {
            for w in [GC_LAUNCH_CNT, GC_LAUNCH_SENSE, GC_STREAM_CNT, GC_STREAM_SENSE, GC_EPOCH] {
                assert!(seen.insert(slice_word(s, w)));
            }
        }
        seen.insert(GC_GROUP_CNT);
        seen.insert(GC_GROUP_SENSE);
        seen.insert(GC_SHRINK_CNT);
        seen.insert(GC_SHRINK_SENSE);
        assert_eq!(seen.len(), 5 * MAX_PIPELINE_DEPTH + 4);
        assert!(seen.iter().all(|w| *w < GROUP_CTRL_SLOTS));
        // The analyzer's word map agrees with the layout.
        assert_eq!(control_word_slots(0, MAX_PIPELINE_DEPTH).len(), 5 * MAX_PIPELINE_DEPTH + 4);
    }

    #[test]
    fn hash_covers_every_layout_dimension() {
        let s = spec();
        let base = PoolControl::layout_hash(&s, 6 << 20, 2, 0, 0);
        let mut t = s.clone();
        t.nranks = 3;
        assert_ne!(PoolControl::layout_hash(&t, 6 << 20, 2, 0, 0), base);
        let mut t = s.clone();
        t.db_region_size = 64 * 256;
        assert_ne!(PoolControl::layout_hash(&t, 6 << 20, 2, 0, 0), base);
        assert_ne!(PoolControl::layout_hash(&s, 12 << 20, 2, 0, 0), base);
        // v5: the configured ring depth is a layout dimension.
        for depth in [1usize, 3, 4, 8] {
            assert_ne!(
                PoolControl::layout_hash(&s, 6 << 20, depth, 0, 0),
                base,
                "depth {depth}"
            );
        }
        // v7: the KV-cache reserve carves the plan window, so it is a
        // layout dimension too.
        for kv in [1usize, 16, 64] {
            assert_ne!(PoolControl::layout_hash(&s, 6 << 20, 2, kv, 0), base, "kv {kv}");
        }
        // v9: the multi-pool topology fingerprint — two distinct fabrics,
        // and both distinct from flat (fingerprint 0).
        let fp2 = crate::fabric::PoolSet::uniform(2, 2).unwrap().fingerprint();
        let fp4 = crate::fabric::PoolSet::uniform(4, 2).unwrap().fingerprint();
        assert_ne!(PoolControl::layout_hash(&s, 6 << 20, 2, 0, fp2), base, "2-pool fabric");
        assert_ne!(PoolControl::layout_hash(&s, 6 << 20, 2, 0, fp4), base, "4-pool fabric");
        assert_ne!(
            PoolControl::layout_hash(&s, 6 << 20, 2, 0, fp2),
            PoolControl::layout_hash(&s, 6 << 20, 2, 0, fp4),
            "distinct fabrics"
        );
    }

    /// v6/v7/v9: the tuner algorithm version, the KV-cache reserve and
    /// the multi-pool topology fingerprint are folded into the
    /// fingerprint, so a build with a different sweep (which could
    /// resolve `auto` launches to different plans), a mapper with a
    /// different reserve (which would carve a different plan window), or
    /// a mapper with a different pool map (which would stage different
    /// two-level plans) fails rendezvous. Pinned by mirroring the hash
    /// input byte-for-byte: bump `TUNER_ALGO_VERSION` and this stays
    /// green, but drop a field from the buffer and this catches the
    /// regression.
    #[test]
    fn hash_covers_the_tuner_algorithm_version_and_kv_reserve() {
        let s = spec();
        let fp = crate::fabric::PoolSet::uniform(2, 2).unwrap().fingerprint();
        let mut buf = [0u8; 80];
        for (i, v) in [
            s.nranks as u64,
            s.ndevices as u64,
            s.device_capacity as u64,
            s.db_region_size as u64,
            6u64 << 20,
            POOL_PROTO_VERSION as u64,
            2u64,
            crate::collectives::tuner::TUNER_ALGO_VERSION,
            48u64,
            fp,
        ]
        .into_iter()
        .enumerate()
        {
            buf[i * 8..(i + 1) * 8].copy_from_slice(&v.to_le_bytes());
        }
        assert_eq!(PoolControl::layout_hash(&s, 6 << 20, 2, 48, fp), crate::util::fnv1a64(&buf));
    }

    /// Satellite (v10): the lease-word timeout arithmetic mirrors the
    /// epoch-word wrap discipline — forward progress is recognized through
    /// the u32 heartbeat wrap, silence is never mistaken for progress, and
    /// the half-range guard rejects implausible backward jumps. A sweep of
    /// probe points around every wrap boundary, like
    /// `epoch_words_wrap_without_ambiguity_at_every_depth` above.
    #[test]
    fn lease_words_wrap_without_ambiguity() {
        let probes = [0u32, 1, 2, 1 << 16, u32::MAX - 2, u32::MAX - 1, u32::MAX];
        for &prev in &probes {
            // Silence: a rank that never beats shows zero gap.
            assert!(!lease_progressed(prev, prev), "prev {prev}");
            assert_eq!(lease_gap(prev, prev), 0);
            // Any plausible number of beats between two probes counts as
            // progress — including across the wrap.
            for gap in [1u32, 2, 3, 1000, (1 << 31) - 1] {
                let cur = prev.wrapping_add(gap);
                assert!(lease_progressed(prev, cur), "prev {prev} gap {gap}");
                assert_eq!(lease_gap(prev, cur), gap);
            }
            // Half-range and beyond reads as silence (a torn/garbage word,
            // or a monitor re-observing an ancient value), not progress.
            for gap in [1u32 << 31, (1 << 31) + 1, u32::MAX] {
                assert!(!lease_progressed(prev, prev.wrapping_add(gap)), "prev {prev} gap {gap}");
            }
        }
        // The wrap itself, explicitly.
        assert!(lease_progressed(u32::MAX, 0));
        assert!(lease_progressed(u32::MAX, 1));
        assert!(!lease_progressed(0, u32::MAX)); // gap 2^32 - 1: backward
    }

    #[test]
    fn heartbeats_and_alive_mask_round_trip() {
        let s = spec();
        let pool = pool_for(&s);
        let ctrl = init_header(&pool, &s);
        // Initialization seeds a full-world alive mask and no shrink.
        assert_eq!(ctrl.alive_mask().unwrap(), 0b11);
        assert_eq!(ctrl.shrink_count().unwrap(), 0);
        // Leases start silent and advance monotonically per beat.
        assert_eq!(ctrl.read_lease(1).unwrap(), 0);
        ctrl.heartbeat(1).unwrap();
        ctrl.heartbeat(1).unwrap();
        assert_eq!(ctrl.read_lease(1).unwrap(), 2);
        assert_eq!(ctrl.read_lease(0).unwrap(), 0, "beats never cross rank slots");
    }

    #[test]
    fn publish_shrink_types_the_generation_error() {
        let s = spec();
        let pool = pool_for(&s);
        let ctrl = init_header(&pool, &s);
        ctrl.check_generation().unwrap();
        let joined = ctrl.generation;
        let new_gen = ctrl.publish_shrink(1).unwrap();
        assert_eq!(new_gen, joined.wrapping_add(1).max(1));
        assert_eq!(ctrl.alive_mask().unwrap(), 0b01, "rank 1's alive bit cleared");
        assert_eq!(ctrl.shrink_count().unwrap(), 1);
        // The stale handle's guard now surfaces the typed WorldShrunk —
        // downcastable, and naming the departed rank.
        let err = ctrl.check_generation().unwrap_err();
        let ws = err.downcast_ref::<WorldShrunk>().expect("WorldShrunk, not stale-mapper");
        assert_eq!(ws.joined_generation, joined);
        assert_eq!(ws.current_generation, new_gen);
        assert_eq!(ws.dead_rank, Some(1));
        assert!(format!("{err:#}").contains("world shrunk"), "{err:#}");
        // The survivors' view at the new generation is clean.
        ctrl.at_generation(new_gen).check_generation().unwrap();
        // A *re-initialization* (no shrink words) still reads as the
        // classic stale-mapper error — the two causes stay distinguishable.
        let fresh = init_header(&pool, &s);
        let err = ctrl.at_generation(new_gen).check_generation().unwrap_err();
        assert!(err.downcast_ref::<WorldShrunk>().is_none());
        assert!(format!("{err:#}").contains("re-initialized"), "{err:#}");
        drop(fresh);
    }

    #[test]
    fn lease_monitor_classifies_live_suspect_dead() {
        let mut mon = LeaseMonitor::new(2, Duration::from_millis(400));
        // Progress -> live, regardless of elapsed time.
        assert_eq!(mon.classify(0, 1, true), RankHealth::Live);
        // Cleared alive bit -> dead immediately, even with a fresh lease.
        assert_eq!(mon.classify(1, 7, false), RankHealth::Dead);
        // Silence walks live -> suspect -> dead against the timeout.
        assert_eq!(mon.classify(0, 1, true), RankHealth::Live);
        std::thread::sleep(Duration::from_millis(250));
        assert_eq!(mon.classify(0, 1, true), RankHealth::Suspect);
        std::thread::sleep(Duration::from_millis(250));
        assert_eq!(mon.classify(0, 1, true), RankHealth::Dead);
        // Progress resurrects a suspect (it was never gone, just slow).
        assert_eq!(mon.classify(0, 2, true), RankHealth::Live);
        let h = WorldHealth {
            ranks: vec![RankHealth::Live, RankHealth::Dead],
        };
        assert!(!h.all_live());
        assert_eq!(h.dead(), vec![1]);
        assert!(h.suspects().is_empty());
    }
}
