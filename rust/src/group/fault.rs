//! Scripted fault injection for the v10 elastic-world conformance suite.
//!
//! A [`FaultPlan`] names one fault and the launch sequence it fires at, so
//! a test (or the CLI's `run --fault` flag) can reproduce a failure mode
//! *deterministically*: the same plan against the same world produces the
//! same torn pool words, the same typed error, the same survivor digests.
//! The four kinds cover the ways a member can wedge a pool world:
//!
//! | spec              | fault                                            |
//! |-------------------|--------------------------------------------------|
//! | `kill@N`          | process exits without cleanup before launch N    |
//! | `stall@N:MS`      | stops stamping its lease for MS ms before launch N |
//! | `stale-gen@N`     | generation word bumped under the world before N  |
//! | `torn-sense@N`    | launch-barrier sense of N's slice torn before N  |
//!
//! The plan only *describes* the fault; applying it is
//! [`ProcessGroup::inject_fault`](crate::group::ProcessGroup::inject_fault)
//! (which returns [`FaultKind::Kill`] to the caller instead of applying
//! it — how the process dies is the caller's business).

use anyhow::{bail, Result};
use std::time::Duration;

/// What goes wrong. See the module table for the on-pool effect of each.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The process dies without running destructors — doorbells stay
    /// rung, barrier counters half-advanced, the lease word silent.
    Kill,
    /// The process stops stamping its liveness lease for the given
    /// duration (it sleeps), driving peers' probes through suspect
    /// toward dead while it is in fact merely slow.
    StallLease(Duration),
    /// The pool generation word moves underneath the live world — what a
    /// rank 0 restart (re-initialization) looks like to everyone else.
    StaleGeneration,
    /// The launch-barrier sense word of the target launch's epoch slice
    /// is torn, as a member crashing mid-barrier would leave it.
    TornSense,
}

/// One scripted fault: `kind` fires right before launch `at_launch`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultPlan {
    pub kind: FaultKind,
    /// Launch sequence the fault fires at (the group's pipelined `seq`
    /// numbering, starting at 0 unless reseeded).
    pub at_launch: u64,
}

impl FaultPlan {
    /// Parse a `kind@launch` spec: `kill@3`, `stall@2:500` (milliseconds),
    /// `stale-gen@1`, `torn-sense@0`.
    pub fn parse(s: &str) -> Result<Self> {
        let Some((kind, rest)) = s.split_once('@') else {
            bail!(
                "fault spec '{s}' must be kind@launch: kill@3, stall@2:500, \
                 stale-gen@1, or torn-sense@0"
            );
        };
        let seq = |t: &str| -> Result<u64> {
            t.parse().map_err(|e| {
                anyhow::anyhow!("bad launch number '{t}' in fault spec '{s}': {e}")
            })
        };
        let kind = match kind {
            "kill" => FaultKind::Kill,
            "stall" => {
                let Some((at, ms)) = rest.split_once(':') else {
                    bail!("stall fault '{s}' must be stall@launch:millis, e.g. stall@2:500");
                };
                let ms: u64 = ms.parse().map_err(|e| {
                    anyhow::anyhow!("bad stall millis '{ms}' in fault spec '{s}': {e}")
                })?;
                return Ok(FaultPlan {
                    kind: FaultKind::StallLease(Duration::from_millis(ms)),
                    at_launch: seq(at)?,
                });
            }
            "stale-gen" => FaultKind::StaleGeneration,
            "torn-sense" => FaultKind::TornSense,
            other => bail!(
                "unknown fault kind '{other}' in '{s}' (kill, stall, stale-gen, \
                 torn-sense)"
            ),
        };
        Ok(FaultPlan {
            kind,
            at_launch: seq(rest)?,
        })
    }

    /// Does this plan fire at launch `seq`?
    pub fn fires(&self, seq: u64) -> bool {
        seq == self.at_launch
    }
}

impl std::str::FromStr for FaultPlan {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        FaultPlan::parse(s)
    }
}

impl std::fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.kind {
            FaultKind::Kill => write!(f, "kill@{}", self.at_launch),
            FaultKind::StallLease(d) => {
                write!(f, "stall@{}:{}", self.at_launch, d.as_millis())
            }
            FaultKind::StaleGeneration => write!(f, "stale-gen@{}", self.at_launch),
            FaultKind::TornSense => write!(f, "torn-sense@{}", self.at_launch),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_kind_and_round_trips() {
        let cases = [
            ("kill@3", FaultKind::Kill, 3),
            (
                "stall@2:500",
                FaultKind::StallLease(Duration::from_millis(500)),
                2,
            ),
            ("stale-gen@1", FaultKind::StaleGeneration, 1),
            ("torn-sense@0", FaultKind::TornSense, 0),
        ];
        for (spec, kind, at) in cases {
            let p = FaultPlan::parse(spec).unwrap();
            assert_eq!(p.kind, kind, "{spec}");
            assert_eq!(p.at_launch, at, "{spec}");
            assert_eq!(p.to_string(), spec, "display round-trips");
            assert!(p.fires(at) && !p.fires(at + 1));
            let via_from_str: FaultPlan = spec.parse().unwrap();
            assert_eq!(via_from_str, p);
        }
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "kill",          // no @launch
            "kill@",         // empty launch
            "kill@x",        // non-numeric launch
            "stall@2",       // missing :millis
            "stall@2:zz",    // non-numeric millis
            "explode@1",     // unknown kind
            "",              // empty
        ] {
            let err = FaultPlan::parse(bad).unwrap_err().to_string();
            assert!(
                err.contains("fault") || err.contains("launch") || err.contains("stall"),
                "unhelpful error for '{bad}': {err}"
            );
        }
    }
}
