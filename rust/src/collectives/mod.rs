//! The eight NCCL-style collective primitives over the CXL pool (paper
//! Table 2), each in the paper's three implementations:
//!
//! - [`CclVariant::All`] — interleaving + fine-grained chunking + doorbell
//!   overlap (the full system),
//! - [`CclVariant::Aggregate`] — interleaving at coarse data-block
//!   granularity, no asynchrony/overlap (barrier between phases),
//! - [`CclVariant::Naive`] — sequential pool placement, no interleaving,
//!   no overlap.
//!
//! A collective is *planned* into per-rank [`ops::RankPlan`]s (two ordered
//! streams of [`ops::Op`]s: writeStream and readStream, §4.4) and then
//! either executed for real by [`crate::exec::Communicator`] or timed in
//! virtual time by [`crate::sim::fabric::SimFabric`]. One algorithm, two
//! backends.
//!
//! Plans are also *statically audited*: [`crate::analysis`] builds a
//! happens-before model of the op streams and checks race freedom,
//! window containment, cross-slice exclusivity, and doorbell-publish
//! uniqueness. [`ValidPlan`] sealing runs the plan-level checks under
//! `debug_assertions`; `ccl analyze` sweeps the whole candidate matrix.

pub mod backend;
pub mod builder;
pub mod cache;
pub mod oracle;
pub mod ops;
pub mod p2p;
pub mod staged;
pub mod tuner;

pub use backend::{run_with_scratch, CollectiveBackend, ExecOutcome};
pub use builder::{plan_collective, plan_collective_dtype};
pub use cache::{CacheStats, PlanCache, PlanKey};
pub use ops::{validate_calls, CollectivePlan, Op, RankPlan, ValidPlan};
pub use p2p::plan_send_recv;
pub use staged::simulate_staged_allreduce;
pub use tuner::{tune_decision, DecisionCache, DecisionKey, TunedDecision};

use crate::tensor::Dtype;
use anyhow::{bail, Result};

/// The eight primitives of paper Table 2.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Primitive {
    AllReduce,
    Broadcast,
    Reduce,
    AllGather,
    ReduceScatter,
    Gather,
    Scatter,
    AllToAll,
}

impl Primitive {
    pub const ALL: [Primitive; 8] = [
        Primitive::AllReduce,
        Primitive::Broadcast,
        Primitive::Reduce,
        Primitive::AllGather,
        Primitive::ReduceScatter,
        Primitive::Gather,
        Primitive::Scatter,
        Primitive::AllToAll,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Primitive::AllReduce => "allreduce",
            Primitive::Broadcast => "broadcast",
            Primitive::Reduce => "reduce",
            Primitive::AllGather => "allgather",
            Primitive::ReduceScatter => "reducescatter",
            Primitive::Gather => "gather",
            Primitive::Scatter => "scatter",
            Primitive::AllToAll => "alltoall",
        }
    }

    pub fn parse(s: &str) -> Result<Primitive> {
        for p in Self::ALL {
            if p.name().eq_ignore_ascii_case(s) {
                return Ok(p);
            }
        }
        bail!(
            "unknown primitive {s:?} (accepted names: allreduce|broadcast|reduce|allgather|\
             reducescatter|gather|scatter|alltoall)"
        )
    }

    /// Communication pattern class (paper Table 2 / §4.3): type 1 is
    /// 1→N or N→1 (root-based), type 2 is N→N.
    pub fn is_root_based(&self) -> bool {
        matches!(
            self,
            Primitive::Broadcast | Primitive::Reduce | Primitive::Gather | Primitive::Scatter
        )
    }

    /// Whether the consumer side performs a reduction.
    pub fn reduces(&self) -> bool {
        matches!(
            self,
            Primitive::AllReduce | Primitive::Reduce | Primitive::ReduceScatter
        )
    }

    /// Per-rank send buffer length in elements for message size `n`
    /// (Table 2 `SendSize`; `n` is the per-rank `N`).
    pub fn send_elems(&self, n: usize, nranks: usize) -> usize {
        match self {
            Primitive::Scatter => n * nranks,
            _ => n,
        }
    }

    /// Per-rank recv buffer length in elements (Table 2 `RecvSize`).
    pub fn recv_elems(&self, n: usize, nranks: usize) -> usize {
        match self {
            Primitive::AllGather | Primitive::Gather => n * nranks,
            Primitive::ReduceScatter => n / nranks,
            _ => n,
        }
    }

    /// Total bytes a rank moves through the pool for F32 messages (used
    /// for bus-bandwidth style reporting in the benches).
    pub fn bytes_on_wire(&self, n: usize, nranks: usize) -> usize {
        self.bytes_on_wire_dtype(n, nranks, Dtype::F32)
    }

    /// Dtype-aware [`Primitive::bytes_on_wire`].
    pub fn bytes_on_wire_dtype(&self, n: usize, nranks: usize, dtype: Dtype) -> usize {
        let b = n * dtype.size_bytes();
        match self {
            Primitive::AllReduce => b + b * (nranks - 1), // write N, read (nr-1)N
            Primitive::Broadcast => b,                    // root writes N, each reads N
            Primitive::Reduce => b,                       // each writes N, root reads (nr-1)N
            Primitive::AllGather => b * nranks,           // write N, read (nr-1)N
            Primitive::ReduceScatter => b,                // write (nr-1)/nr N, read same
            Primitive::Gather => b,
            Primitive::Scatter => b,
            Primitive::AllToAll => b,
        }
    }
}

impl std::fmt::Display for Primitive {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// The three CXL-CCL implementations evaluated in §5.1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CclVariant {
    /// Full system: interleave + chunking + doorbell overlap.
    All,
    /// Interleave at data-block granularity only; barrier, no overlap.
    Aggregate,
    /// Sequential placement; barrier, no overlap, no interleave.
    Naive,
}

impl CclVariant {
    pub const ALL: [CclVariant; 3] = [CclVariant::All, CclVariant::Aggregate, CclVariant::Naive];

    pub fn name(&self) -> &'static str {
        match self {
            CclVariant::All => "cxl-ccl-all",
            CclVariant::Aggregate => "cxl-ccl-aggregate",
            CclVariant::Naive => "cxl-ccl-naive",
        }
    }

    /// Parse a *fixed* variant name. The `auto` spelling is not a fixed
    /// variant — it defers the (variant, chunks) choice to the tuner — so
    /// callers that accept `auto` (the CLI, config files) must check for it
    /// before calling this and route through [`CclConfig::auto`].
    pub fn parse(s: &str) -> Result<CclVariant> {
        match s.to_ascii_lowercase().as_str() {
            "all" | "cxl-ccl-all" => Ok(CclVariant::All),
            "aggregate" | "cxl-ccl-aggregate" => Ok(CclVariant::Aggregate),
            "naive" | "cxl-ccl-naive" => Ok(CclVariant::Naive),
            "auto" => bail!(
                "variant \"auto\" is not a fixed variant: it defers the choice to the \
                 tuner — use CclConfig::auto() (accepted fixed names: all|cxl-ccl-all|\
                 aggregate|cxl-ccl-aggregate|naive|cxl-ccl-naive)"
            ),
            _ => bail!(
                "unknown variant {s:?} (accepted names: auto|all|cxl-ccl-all|aggregate|\
                 cxl-ccl-aggregate|naive|cxl-ccl-naive; \"auto\" defers the choice to \
                 the tuner)"
            ),
        }
    }

    /// Build a config; `chunks` (the §5.4 slicing factor) only applies to
    /// `All` — the other variants are single-chunk by definition.
    pub fn config(self, chunks: usize) -> CclConfig {
        CclConfig::new(self, chunks)
    }
}

/// How a launch's (variant, chunks) pair was chosen.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TuneMode {
    /// The caller pinned `variant`/`chunks` explicitly.
    Fixed,
    /// Defer the choice to [`tuner::tune_decision`] at launch time: the
    /// launch surface resolves the config into a concrete `Fixed` one
    /// (a pure function of the cluster spec and launch shape) before any
    /// plan-cache lookup or member-agreement comparison sees it.
    Auto,
}

/// Configuration of one collective invocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CclConfig {
    pub variant: CclVariant,
    /// Slicing factor: chunks per data block (paper §5.4; 4–8 is best).
    pub chunks: usize,
    /// Root rank for the root-based primitives.
    pub root: usize,
    /// `Fixed` for explicitly pinned configs; `Auto` defers the
    /// variant/chunks choice to the tuner at launch. `variant`/`chunks`
    /// of an `Auto` config are placeholders (never planned against).
    pub mode: TuneMode,
}

impl CclConfig {
    pub fn new(variant: CclVariant, chunks: usize) -> Self {
        let chunks = match variant {
            CclVariant::All => chunks.max(1),
            // Aggregate operates at data-block granularity; Naive has no
            // chunking at all (§5.1).
            CclVariant::Aggregate | CclVariant::Naive => 1,
        };
        Self {
            variant,
            chunks,
            root: 0,
            mode: TuneMode::Fixed,
        }
    }

    pub fn with_root(mut self, root: usize) -> Self {
        self.root = root;
        self
    }

    /// Defer the (variant, chunks) choice to the tuner: the launch surface
    /// resolves this config through [`tuner::tune_decision`] — a pure
    /// function of the cluster spec, pipeline ring, and launch shape, so
    /// every rank of a pool-mode group resolves identically. Pair with
    /// [`CclConfig::with_root`] for root-based primitives. Inspect the
    /// resolved choice via `ProcessGroup::resolve_auto`.
    pub fn auto() -> Self {
        Self {
            variant: CclVariant::All,
            chunks: 8,
            root: 0,
            mode: TuneMode::Auto,
        }
    }

    /// Whether this config defers to the tuner at launch.
    pub fn is_auto(&self) -> bool {
        self.mode == TuneMode::Auto
    }

    /// Human-readable label for banners and reports: the pinned
    /// variant + chunk count, or `auto` before the tuner has resolved it.
    pub fn describe(&self) -> String {
        match self.mode {
            TuneMode::Auto => "auto".to_string(),
            TuneMode::Fixed => format!("{} x{}", self.variant.name(), self.chunks),
        }
    }

    /// Paper default: the §5.4 sweet spot.
    #[deprecated(note = "use `CclConfig::auto()` (tuner-resolved) or pin a variant with \
                         `CclVariant::All.config(8)`")]
    pub fn default_all() -> Self {
        CclConfig::new(CclVariant::All, 8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primitive_parse_round_trips() {
        for p in Primitive::ALL {
            assert_eq!(Primitive::parse(p.name()).unwrap(), p);
            assert_eq!(Primitive::parse(&p.name().to_uppercase()).unwrap(), p);
        }
        assert!(Primitive::parse("sendrecv").is_err());
    }

    #[test]
    fn table2_sizes() {
        // Table 2 with N = 12, nranks = 3.
        assert_eq!(Primitive::AllReduce.send_elems(12, 3), 12);
        assert_eq!(Primitive::AllReduce.recv_elems(12, 3), 12);
        assert_eq!(Primitive::AllGather.recv_elems(12, 3), 36);
        assert_eq!(Primitive::ReduceScatter.recv_elems(12, 3), 4);
        assert_eq!(Primitive::Gather.recv_elems(12, 3), 36);
        assert_eq!(Primitive::Scatter.send_elems(12, 3), 36);
        assert_eq!(Primitive::Scatter.recv_elems(12, 3), 12);
        assert_eq!(Primitive::AllToAll.send_elems(12, 3), 12);
        assert_eq!(Primitive::AllToAll.recv_elems(12, 3), 12);
    }

    #[test]
    fn pattern_classes_match_paper() {
        assert!(Primitive::Broadcast.is_root_based());
        assert!(Primitive::Scatter.is_root_based());
        assert!(!Primitive::AllReduce.is_root_based());
        assert!(!Primitive::AllToAll.is_root_based());
        assert!(Primitive::ReduceScatter.reduces());
        assert!(!Primitive::AllGather.reduces());
    }

    #[test]
    fn aggregate_and_naive_force_single_chunk() {
        assert_eq!(CclVariant::All.config(8).chunks, 8);
        assert_eq!(CclVariant::Aggregate.config(8).chunks, 1);
        assert_eq!(CclVariant::Naive.config(8).chunks, 1);
        assert_eq!(CclVariant::All.config(0).chunks, 1);
    }

    #[test]
    fn variant_parse() {
        assert_eq!(CclVariant::parse("all").unwrap(), CclVariant::All);
        assert_eq!(
            CclVariant::parse("CXL-CCL-Naive").unwrap(),
            CclVariant::Naive
        );
        // Unknown spellings enumerate every accepted name, auto included.
        let err = CclVariant::parse("turbo").unwrap_err().to_string();
        for name in ["auto", "all", "aggregate", "naive", "cxl-ccl-all"] {
            assert!(err.contains(name), "{err:?} should mention {name:?}");
        }
        // `auto` is not a fixed variant; the error routes to the config
        // entry point instead.
        let err = CclVariant::parse("auto").unwrap_err().to_string();
        assert!(err.contains("CclConfig::auto()"), "{err:?}");
    }

    #[test]
    fn primitive_parse_error_enumerates_names() {
        let err = Primitive::parse("sendrecv").unwrap_err().to_string();
        for p in Primitive::ALL {
            assert!(err.contains(p.name()), "{err:?} should mention {:?}", p.name());
        }
    }

    #[test]
    fn auto_config_is_marked_and_fixed_configs_are_not() {
        let auto = CclConfig::auto();
        assert!(auto.is_auto());
        assert_eq!(auto.mode, TuneMode::Auto);
        assert!(auto.with_root(2).is_auto(), "with_root keeps the mode");
        assert_eq!(auto.with_root(2).root, 2);
        for v in CclVariant::ALL {
            assert!(!v.config(4).is_auto());
            assert_eq!(v.config(4).mode, TuneMode::Fixed);
        }
        // The deprecated paper-default constructor still resolves to the
        // pinned §5.4 sweet spot, not to auto.
        #[allow(deprecated)]
        let legacy = CclConfig::default_all();
        assert_eq!(legacy, CclVariant::All.config(8));
        assert!(!legacy.is_auto());
    }
}
