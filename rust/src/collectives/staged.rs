//! Staged AllReduce — the extension the paper's §5.2 discussion motivates.
//!
//! The paper's one-shot AllReduce has every rank read *all* other ranks'
//! full buffers ("partially reduced results cannot be reused across
//! ranks"), which is why it only reaches ~1.05× of the IB ring at large
//! sizes and scales poorly (Fig. 10). The classic fix — exactly what the
//! ring does — is to stage it as **ReduceScatter followed by AllGather**:
//! each rank reduces only its 1/nranks slice (reusing everyone's partial
//! work) and then re-distributes.
//!
//! On the pool this halves the per-rank read volume from `(nr−1)·N` to
//! `2·(nr−1)·N/nr`, at the cost of a second synchronization phase. The
//! ablation bench (`fig9_collectives` prints it; `hotpath` measures it for
//! real) shows where the trade crosses over.

use crate::collectives::backend::CollectiveBackend;
use crate::collectives::builder::plan_collective;
use crate::collectives::{CclConfig, Primitive};
use crate::exec::Communicator;
use crate::pool::PoolLayout;
use crate::sim::SimFabric;
use crate::tensor::{views_f32, views_f32_mut};
use crate::topology::ClusterSpec;
use anyhow::{ensure, Result};
use std::time::Duration;

/// Virtual-time cost of the staged AllReduce (RS phase + AG phase).
pub fn simulate_staged_allreduce(
    spec: &ClusterSpec,
    layout: &PoolLayout,
    cfg: &CclConfig,
    n_elems: usize,
) -> Result<f64> {
    ensure!(
        n_elems % spec.nranks == 0,
        "staged allreduce needs nranks-divisible length"
    );
    let fab = SimFabric::new(*layout);
    let rs = plan_collective(Primitive::ReduceScatter, spec, layout, cfg, n_elems)?;
    let ag = plan_collective(Primitive::AllGather, spec, layout, cfg, n_elems / spec.nranks)?;
    Ok(fab.run(&rs, &[], &mut [])?.seconds() + fab.run(&ag, &[], &mut [])?.seconds())
}

impl Communicator {
    /// In-place staged AllReduce: ReduceScatter + AllGather through the
    /// pool. Requires `bufs[r].len()` divisible by nranks.
    pub fn all_reduce_staged_f32(
        &self,
        bufs: &mut [Vec<f32>],
        cfg: &CclConfig,
    ) -> Result<Duration> {
        let nr = self.spec().nranks;
        let n = bufs.first().map(|b| b.len()).unwrap_or(0);
        ensure!(n % nr == 0, "buffer length {n} not divisible by {nr} ranks");
        let seg = n / nr;
        let sends: Vec<Vec<f32>> = bufs.to_vec();
        let t0 = std::time::Instant::now();
        // Phase 1: each rank ends up owning the reduced slice r.
        let mut slices = vec![vec![0.0f32; seg]; nr];
        {
            let send_views = views_f32(&sends);
            let mut recv_views = views_f32_mut(&mut slices);
            self.collective(Primitive::ReduceScatter, cfg, n, &send_views, &mut recv_views)?;
        }
        // Phase 2: gather the reduced slices straight back into `bufs`.
        {
            let send_views = views_f32(&slices);
            let mut recv_views = views_f32_mut(bufs);
            self.collective(Primitive::AllGather, cfg, seg, &send_views, &mut recv_views)?;
        }
        Ok(t0.elapsed())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{oracle, CclVariant};
    use crate::util::SplitMix64;

    #[test]
    fn staged_matches_oracle() {
        let spec = ClusterSpec::new(4, 6, 8 << 20);
        let comm = Communicator::shm(&spec).unwrap();
        let n = 4 * 1024;
        let mut rng = SplitMix64::new(3);
        let mut bufs: Vec<Vec<f32>> = (0..4)
            .map(|_| {
                let mut v = vec![0.0f32; n];
                rng.fill_f32(&mut v);
                v
            })
            .collect();
        let want = oracle::expected(Primitive::AllReduce, &bufs, n, 0);
        comm.all_reduce_staged_f32(&mut bufs, &CclVariant::All.config(8))
            .unwrap();
        for r in 0..4 {
            for (g, e) in bufs[r].iter().zip(&want[r]) {
                assert!((g - e).abs() <= 1e-4 * e.abs().max(1.0));
            }
        }
    }

    #[test]
    fn staged_beats_oneshot_at_scale_in_virtual_time() {
        // The §5.2 limitation: one-shot reads (nr-1)·N per rank. Staged
        // reads 2·(nr-1)·N/nr. At 6 ranks the staged plan must win.
        let spec = ClusterSpec::new(6, 6, 1 << 30);
        let layout = PoolLayout::from_spec(&spec).unwrap();
        let cfg = CclVariant::All.config(8);
        let n = (64 << 20) / 4 / 6 * 6; // ~64 MiB per rank, divisible by 6
        let staged = simulate_staged_allreduce(&spec, &layout, &cfg, n).unwrap();
        let oneshot = {
            let fab = SimFabric::new(layout);
            let plan = plan_collective(Primitive::AllReduce, &spec, &layout, &cfg, n).unwrap();
            fab.simulate(&plan).unwrap().total_time
        };
        assert!(
            staged < oneshot * 0.7,
            "staged {staged} should clearly beat one-shot {oneshot} at 6 ranks"
        );
    }

    #[test]
    fn indivisible_length_rejected() {
        let spec = ClusterSpec::new(4, 6, 8 << 20);
        let comm = Communicator::shm(&spec).unwrap();
        let mut bufs = vec![vec![0.0f32; 1001]; 4];
        assert!(comm
            .all_reduce_staged_f32(&mut bufs, &CclVariant::All.config(8))
            .is_err());
    }
}
