//! Point-to-point send/recv over the pool (the `ncclSend`/`ncclRecv`
//! analogue — excluded from the paper's evaluation because it is not a
//! collective, but required by any CCL users would adopt).
//!
//! A send/recv pair is planned with the same machinery as the collectives:
//! the sender publishes chunks to devices chosen by the type-1 round-robin
//! (the transfer is 1→1, so spreading across devices buys the aggregate
//! bandwidth of the pool up to the sender's DMA-engine cap), and the
//! receiver chases the chunk doorbells.

use crate::chunking::{effective_chunks, split_aligned};
use crate::collectives::ops::{CollectivePlan, Op, RankPlan, ValidPlan};
use crate::collectives::{CclConfig, CclVariant, Primitive};
use crate::interleave;
use crate::pool::PoolLayout;
use crate::topology::ClusterSpec;
use anyhow::{bail, Result};

/// Plan a single send/recv: `src` rank's `n_elems` f32 buffer lands in
/// `dst` rank's recv buffer. Returned as a sealed [`ValidPlan`] so both
/// the executor and the simulator run it unchanged (non-participating
/// ranks get empty streams).
pub fn plan_send_recv(
    spec: &ClusterSpec,
    layout: &PoolLayout,
    cfg: &CclConfig,
    src: usize,
    dst: usize,
    n_elems: usize,
) -> Result<ValidPlan> {
    spec.validate().map_err(|e| anyhow::anyhow!(e))?;
    if src >= spec.nranks || dst >= spec.nranks {
        bail!("send/recv ranks ({src} -> {dst}) out of range ({} ranks)", spec.nranks);
    }
    if src == dst {
        bail!("send/recv requires distinct ranks (got {src} -> {src})");
    }
    if n_elems == 0 {
        bail!("message size must be positive");
    }
    let n_bytes = n_elems * 4;
    let nd = layout.device_span;
    // Spread the message across all devices (type-1, data_id = piece).
    let npieces = if cfg.variant == CclVariant::Naive { 1 } else { nd };
    let pieces = split_aligned(n_bytes, npieces);
    let stride = pieces.iter().map(|p| p.len).max().unwrap().div_ceil(64) * 64;
    let ix = crate::chunking::DoorbellIndexer::new(nd.max(spec.nranks), cfg.chunks);
    if ix.slots_needed(spec.nranks) > layout.doorbell_slots() {
        bail!("doorbell region too small for send/recv slicing");
    }

    let mut ranks: Vec<RankPlan> = (0..spec.nranks).map(RankPlan::new).collect();
    for (b, piece) in pieces.iter().enumerate() {
        let addr = interleave::type1(layout, b, stride)?;
        let chunks = effective_chunks(cfg.chunks, piece.len, n_bytes);
        for (ci, ch) in split_aligned(piece.len, chunks).into_iter().enumerate() {
            ranks[src].write_ops.push(Op::Write {
                pool_off: addr.pool_offset + ch.offset,
                src_off: piece.offset + ch.offset,
                len: ch.len,
            });
            if cfg.variant == CclVariant::All {
                ranks[src].write_ops.push(Op::SetDoorbell { db: ix.index(src, b, ci) });
                ranks[dst].read_ops.push(Op::WaitDoorbell { db: ix.index(src, b, ci) });
            }
            ranks[dst].read_ops.push(Op::Read {
                pool_off: addr.pool_offset + ch.offset,
                dst_off: piece.offset + ch.offset,
                len: ch.len,
            });
        }
    }
    if cfg.variant != CclVariant::All {
        for rp in &mut ranks {
            rp.write_ops.push(Op::Barrier);
            rp.read_ops.insert(0, Op::Barrier);
        }
    }
    let plan = CollectivePlan {
        // Reported as Broadcast-shaped for accounting (1 writer, 1 reader).
        primitive: Primitive::Broadcast,
        variant: cfg.variant,
        nranks: spec.nranks,
        n_elems,
        dtype: crate::tensor::Dtype::F32,
        send_elems: n_elems,
        recv_elems: n_elems,
        ranks,
    };
    ValidPlan::new(plan, layout.pool_size())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::Communicator;
    use crate::util::SplitMix64;

    #[test]
    fn send_recv_delivers_payload() {
        let spec = ClusterSpec::new(3, 6, 8 << 20);
        let comm = Communicator::shm(&spec).unwrap();
        let layout = *comm.layout();
        let cfg = CclVariant::All.config(8);
        let n = 3 * 4099; // ragged
        let plan = plan_send_recv(&spec, &layout, &cfg, 2, 0, n).unwrap();
        plan.validate(layout.pool_size()).unwrap();
        let mut rng = SplitMix64::new(77);
        let mut payload = vec![0.0f32; n];
        rng.fill_f32(&mut payload);
        let sends = vec![vec![0.0f32; n], vec![0.0f32; n], payload.clone()];
        let mut recvs = vec![vec![0.0f32; n]; 3];
        let send_views = crate::tensor::views_f32(&sends);
        let mut recv_views = crate::tensor::views_f32_mut(&mut recvs);
        comm.run_plan_views(&plan, &send_views, &mut recv_views).unwrap();
        drop(recv_views);
        assert_eq!(recvs[0], payload, "payload must arrive intact");
        assert!(recvs[1].iter().all(|v| *v == 0.0), "bystander untouched");
    }

    #[test]
    fn send_recv_spreads_across_devices() {
        let spec = ClusterSpec::new(2, 6, 8 << 20);
        let layout = PoolLayout::from_spec(&spec).unwrap();
        let plan =
            plan_send_recv(&spec, &layout, &CclVariant::All.config(8), 0, 1, 6 * 65536).unwrap();
        let devices: std::collections::HashSet<usize> = plan.ranks[0]
            .write_ops
            .iter()
            .filter_map(|op| match op {
                Op::Write { pool_off, .. } => Some(layout.stacking.device_of(*pool_off)),
                _ => None,
            })
            .collect();
        assert_eq!(devices.len(), 6, "message should stripe all devices");
    }

    #[test]
    fn invalid_pairs_rejected() {
        let spec = ClusterSpec::new(2, 6, 8 << 20);
        let layout = PoolLayout::from_spec(&spec).unwrap();
        let cfg = CclVariant::All.config(8);
        assert!(plan_send_recv(&spec, &layout, &cfg, 0, 0, 64).is_err());
        assert!(plan_send_recv(&spec, &layout, &cfg, 0, 5, 64).is_err());
        assert!(plan_send_recv(&spec, &layout, &cfg, 0, 1, 0).is_err());
    }

    #[test]
    fn naive_variant_uses_barrier() {
        let spec = ClusterSpec::new(2, 6, 8 << 20);
        let layout = PoolLayout::from_spec(&spec).unwrap();
        let plan =
            plan_send_recv(&spec, &layout, &CclVariant::Naive.config(1), 0, 1, 1024).unwrap();
        assert!(plan.ranks[0].write_ops.contains(&Op::Barrier));
        assert!(!plan.ranks[1]
            .read_ops
            .iter()
            .any(|o| matches!(o, Op::WaitDoorbell { .. })));
    }
}
