//! Pure in-memory reference semantics for every primitive (Table 2).
//!
//! The executor and simulator are tested against these oracles; they are the
//! rust-side analogue of `python/compile/kernels/ref.py`.

use crate::collectives::Primitive;

/// Compute the expected recv buffer of every rank.
///
/// `sends[r]` is rank r's send buffer (Table 2 `SendSize` elements);
/// returns one Table 2 `RecvSize` buffer per rank. Ranks that receive
/// nothing (non-root Gather/Reduce) get zero-filled buffers, matching the
/// executor's untouched-recv convention.
pub fn expected(primitive: Primitive, sends: &[Vec<f32>], n: usize, root: usize) -> Vec<Vec<f32>> {
    let nr = sends.len();
    assert!(root < nr);
    match primitive {
        Primitive::AllReduce => {
            let mut sum = vec![0.0f32; n];
            for s in sends {
                for (a, b) in sum.iter_mut().zip(s) {
                    *a += b;
                }
            }
            vec![sum; nr]
        }
        Primitive::Broadcast => vec![sends[root][..n].to_vec(); nr],
        Primitive::Reduce => {
            let mut out = vec![vec![0.0f32; n]; nr];
            for s in sends {
                for (a, b) in out[root].iter_mut().zip(s) {
                    *a += b;
                }
            }
            out
        }
        Primitive::AllGather => {
            let mut cat = Vec::with_capacity(n * nr);
            for s in sends {
                cat.extend_from_slice(&s[..n]);
            }
            vec![cat; nr]
        }
        Primitive::ReduceScatter => {
            let seg = n / nr;
            (0..nr)
                .map(|r| {
                    let mut acc = vec![0.0f32; seg];
                    for s in sends {
                        for (a, b) in acc.iter_mut().zip(&s[r * seg..(r + 1) * seg]) {
                            *a += b;
                        }
                    }
                    acc
                })
                .collect()
        }
        Primitive::Gather => {
            let mut out = vec![vec![0.0f32; n * nr]; nr];
            for (s, send) in sends.iter().enumerate() {
                out[root][s * n..(s + 1) * n].copy_from_slice(&send[..n]);
            }
            out
        }
        Primitive::Scatter => (0..nr)
            .map(|r| sends[root][r * n..(r + 1) * n].to_vec())
            .collect(),
        Primitive::AllToAll => {
            let seg = n / nr;
            (0..nr)
                .map(|r| {
                    let mut out = vec![0.0f32; n];
                    for (s, send) in sends.iter().enumerate() {
                        out[s * seg..(s + 1) * seg]
                            .copy_from_slice(&send[r * seg..(r + 1) * seg]);
                    }
                    out
                })
                .collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sends(nr: usize, len: usize) -> Vec<Vec<f32>> {
        (0..nr)
            .map(|r| (0..len).map(|i| (r * 100 + i) as f32).collect())
            .collect()
    }

    #[test]
    fn allreduce_sums() {
        let out = expected(Primitive::AllReduce, &sends(3, 4), 4, 0);
        assert_eq!(out[0], vec![300.0, 303.0, 306.0, 309.0]);
        assert_eq!(out[1], out[0]);
    }

    #[test]
    fn broadcast_copies_root() {
        let out = expected(Primitive::Broadcast, &sends(3, 4), 4, 1);
        for r in 0..3 {
            assert_eq!(out[r], vec![100.0, 101.0, 102.0, 103.0]);
        }
    }

    #[test]
    fn reduce_only_root_nonzero() {
        let out = expected(Primitive::Reduce, &sends(3, 2), 2, 2);
        assert_eq!(out[2], vec![300.0, 303.0]);
        assert_eq!(out[0], vec![0.0, 0.0]);
    }

    #[test]
    fn allgather_concatenates_by_rank() {
        let out = expected(Primitive::AllGather, &sends(2, 2), 2, 0);
        assert_eq!(out[0], vec![0.0, 1.0, 100.0, 101.0]);
        assert_eq!(out[1], out[0]);
    }

    #[test]
    fn reducescatter_segments() {
        let out = expected(Primitive::ReduceScatter, &sends(2, 4), 4, 0);
        // seg = 2; rank 0 gets sum of first halves, rank 1 second halves.
        assert_eq!(out[0], vec![100.0, 102.0]);
        assert_eq!(out[1], vec![104.0, 106.0]);
    }

    #[test]
    fn gather_places_by_source() {
        let out = expected(Primitive::Gather, &sends(2, 2), 2, 1);
        assert_eq!(out[1], vec![0.0, 1.0, 100.0, 101.0]);
        assert_eq!(out[0], vec![0.0; 4]);
    }

    #[test]
    fn scatter_slices_root_buffer() {
        let root_buf: Vec<f32> = (0..6).map(|i| i as f32).collect();
        let s = vec![root_buf, vec![0.0; 6]];
        let out = expected(Primitive::Scatter, &s, 3, 0);
        assert_eq!(out[0], vec![0.0, 1.0, 2.0]);
        assert_eq!(out[1], vec![3.0, 4.0, 5.0]);
    }

    #[test]
    fn alltoall_transposes_segments() {
        let out = expected(Primitive::AllToAll, &sends(2, 4), 4, 0);
        // rank0 recv: [s0 seg0, s1 seg0] = [0,1, 100,101]
        assert_eq!(out[0], vec![0.0, 1.0, 100.0, 101.0]);
        assert_eq!(out[1], vec![2.0, 3.0, 102.0, 103.0]);
    }
}
