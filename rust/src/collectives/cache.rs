//! Plan caching for steady-state loops.
//!
//! Training steps issue the same collective shape every iteration (the FSDP
//! loop's per-step AllGather/ReduceScatter); replanning each time is pure
//! overhead. [`PlanCache`] memoizes [`plan_collective_dtype`] outputs under
//! a [`PlanKey`] so repeated launches reuse the immutable [`CollectivePlan`]
//! behind an `Arc`. Hit/miss counters make the reuse observable (and
//! testable).

use crate::collectives::builder::plan_collective_dtype;
use crate::collectives::ops::CollectivePlan;
use crate::collectives::{CclConfig, CclVariant, Primitive};
use crate::pool::PoolLayout;
use crate::tensor::Dtype;
use crate::topology::ClusterSpec;
use anyhow::Result;
use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Everything a plan depends on. Two launches with equal keys are
/// guaranteed identical plans (planning is deterministic).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub primitive: Primitive,
    pub variant: CclVariant,
    pub chunks: usize,
    pub root: usize,
    pub nranks: usize,
    pub ndevices: usize,
    /// Device capacity and doorbell region also shape placement, so they
    /// are part of the key even though a single communicator never varies
    /// them.
    pub device_capacity: usize,
    pub db_region_size: usize,
    pub n_elems: usize,
    pub dtype: Dtype,
}

impl PlanKey {
    pub fn new(
        primitive: Primitive,
        cfg: &CclConfig,
        spec: &ClusterSpec,
        n_elems: usize,
        dtype: Dtype,
    ) -> Self {
        Self {
            primitive,
            variant: cfg.variant,
            chunks: cfg.chunks,
            root: cfg.root,
            nranks: spec.nranks,
            ndevices: spec.ndevices,
            device_capacity: spec.device_capacity,
            db_region_size: spec.db_region_size,
            n_elems,
            dtype,
        }
    }

    /// Reconstruct the config this key was built from.
    pub fn config(&self) -> CclConfig {
        let mut cfg = CclConfig::new(self.variant, self.chunks);
        cfg.root = self.root;
        cfg
    }
}

/// Cache hit/miss counters (monotonic over the cache's lifetime).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: usize,
    pub misses: usize,
}

/// Thread-safe memo of planned collectives.
#[derive(Debug, Default)]
pub struct PlanCache {
    plans: Mutex<HashMap<PlanKey, Arc<CollectivePlan>>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
}

impl PlanCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Return the cached plan for this shape, planning it on first use.
    pub fn get_or_plan(
        &self,
        spec: &ClusterSpec,
        layout: &PoolLayout,
        primitive: Primitive,
        cfg: &CclConfig,
        n_elems: usize,
        dtype: Dtype,
    ) -> Result<Arc<CollectivePlan>> {
        let key = PlanKey::new(primitive, cfg, spec, n_elems, dtype);
        if let Some(plan) = self.plans.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::clone(plan));
        }
        // Plan outside the lock: planning can be slow and racing planners
        // produce identical plans, so the first insert simply wins. The
        // insert's vacancy decides hit-vs-miss, keeping the invariant
        // `misses == number of cached shapes` even under concurrent first
        // launches.
        let plan = Arc::new(plan_collective_dtype(
            primitive, spec, layout, cfg, n_elems, dtype,
        )?);
        match self.plans.lock().unwrap().entry(key) {
            Entry::Occupied(e) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Ok(Arc::clone(e.get()))
            }
            Entry::Vacant(e) => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                Ok(Arc::clone(e.insert(plan)))
            }
        }
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
        }
    }

    /// Number of distinct plans currently cached.
    pub fn len(&self) -> usize {
        self.plans.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every cached plan (counters are preserved).
    pub fn clear(&self) {
        self.plans.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hits_return_the_same_arc_and_count() {
        let spec = ClusterSpec::new(3, 6, 4 << 20);
        let layout = PoolLayout::from_spec(&spec).unwrap();
        let cache = PlanCache::new();
        let cfg = CclVariant::All.config(4);
        let a = cache
            .get_or_plan(&spec, &layout, Primitive::AllGather, &cfg, 3 * 256, Dtype::F32)
            .unwrap();
        let b = cache
            .get_or_plan(&spec, &layout, Primitive::AllGather, &cfg, 3 * 256, Dtype::F32)
            .unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second lookup must reuse the plan");
        assert_eq!(cache.stats(), CacheStats { hits: 1, misses: 1 });
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn dtype_and_shape_are_part_of_the_key() {
        let spec = ClusterSpec::new(3, 6, 4 << 20);
        let layout = PoolLayout::from_spec(&spec).unwrap();
        let cache = PlanCache::new();
        let cfg = CclVariant::All.config(4);
        for (n, d) in [(3 * 256, Dtype::F32), (3 * 256, Dtype::U8), (3 * 512, Dtype::F32)] {
            cache
                .get_or_plan(&spec, &layout, Primitive::AllGather, &cfg, n, d)
                .unwrap();
        }
        assert_eq!(cache.len(), 3);
        assert_eq!(cache.stats().hits, 0);
    }

    #[test]
    fn planning_errors_are_not_cached() {
        let spec = ClusterSpec::new(3, 6, 4 << 20);
        let layout = PoolLayout::from_spec(&spec).unwrap();
        let cache = PlanCache::new();
        let cfg = CclConfig::default_all();
        // Not divisible by nranks -> plan error.
        assert!(cache
            .get_or_plan(&spec, &layout, Primitive::AllToAll, &cfg, 1000, Dtype::F32)
            .is_err());
        assert!(cache.is_empty());
        assert_eq!(cache.stats().misses, 0);
    }

    #[test]
    fn key_reconstructs_config() {
        let spec = ClusterSpec::new(3, 6, 4 << 20);
        let cfg = CclVariant::All.config(8).with_root(2);
        let key = PlanKey::new(Primitive::Broadcast, &cfg, &spec, 1024, Dtype::F16);
        assert_eq!(key.config(), cfg);
        assert_eq!(key.dtype, Dtype::F16);
    }
}
