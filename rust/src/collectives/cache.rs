//! Plan caching for steady-state loops.
//!
//! Training steps issue the same collective shape every iteration (the FSDP
//! loop's per-step AllGather/ReduceScatter); replanning each time is pure
//! overhead. [`PlanCache`] memoizes [`plan_collective_dtype`] outputs under
//! a [`PlanKey`] so repeated launches reuse the immutable, pre-validated
//! [`ValidPlan`] behind an `Arc` — steady-state launches therefore skip
//! `CollectivePlan::validate` entirely (the v3 launch surface accepts only
//! `ValidPlan`s). Hit/miss/eviction counters make the behaviour observable
//! (and testable).
//!
//! The cache is **bounded**: at most `capacity` distinct shapes are kept,
//! evicting the least-recently-used plan when a new shape arrives at a full
//! cache. Long sweeps over many shapes (the fig. 9/10 harnesses, parameter
//! searches) therefore cannot grow it without limit.
//!
//! Because cached plans are sealed [`ValidPlan`]s, a cache hit also reuses
//! the [`crate::analysis`] audit that sealing ran (in debug builds): the
//! static race/reuse checks happen once per shape, never per launch.

use crate::collectives::builder::plan_collective_dtype;
use crate::collectives::ops::ValidPlan;
use crate::collectives::{CclConfig, CclVariant, Primitive};
use crate::pool::PoolLayout;
use crate::tensor::Dtype;
use crate::topology::ClusterSpec;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Everything a plan depends on. Two launches with equal keys are
/// guaranteed identical plans (planning is deterministic).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub primitive: Primitive,
    pub variant: CclVariant,
    pub chunks: usize,
    pub root: usize,
    pub nranks: usize,
    pub ndevices: usize,
    /// Device capacity and doorbell region also shape placement, so they
    /// are part of the key even though a single communicator never varies
    /// them.
    pub device_capacity: usize,
    pub db_region_size: usize,
    /// The layout *windows* the plan was placed into (doorbell slots and
    /// devices). Since the pipelined launch surface, one group plans the
    /// same shape against each of its N epoch-slice views — N distinct
    /// plans — so the window is part of the key.
    pub db_slot_base: usize,
    pub db_slot_span: usize,
    pub device_base: usize,
    pub device_span: usize,
    pub n_elems: usize,
    pub dtype: Dtype,
}

impl PlanKey {
    pub fn new(
        primitive: Primitive,
        cfg: &CclConfig,
        spec: &ClusterSpec,
        layout: &PoolLayout,
        n_elems: usize,
        dtype: Dtype,
    ) -> Self {
        Self {
            primitive,
            variant: cfg.variant,
            chunks: cfg.chunks,
            root: cfg.root,
            nranks: spec.nranks,
            ndevices: spec.ndevices,
            device_capacity: spec.device_capacity,
            db_region_size: spec.db_region_size,
            db_slot_base: layout.db_slot_base,
            db_slot_span: layout.db_slot_span,
            device_base: layout.device_base,
            device_span: layout.device_span,
            n_elems,
            dtype,
        }
    }

    /// Reconstruct the config this key was built from.
    pub fn config(&self) -> CclConfig {
        let mut cfg = CclConfig::new(self.variant, self.chunks);
        cfg.root = self.root;
        cfg
    }
}

/// Cache hit/miss/eviction counters (monotonic over the cache's lifetime).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: usize,
    pub misses: usize,
    /// Plans dropped to keep the cache within its LRU capacity.
    pub evictions: usize,
}

struct LruState {
    /// Plan + last-touched tick per shape.
    plans: HashMap<PlanKey, (ValidPlan, u64)>,
    /// Monotonic access clock.
    tick: u64,
}

/// Thread-safe, LRU-bounded memo of planned (and validated) collectives.
pub struct PlanCache {
    state: Mutex<LruState>,
    capacity: usize,
    hits: AtomicUsize,
    misses: AtomicUsize,
    evictions: AtomicUsize,
}

impl Default for PlanCache {
    fn default() -> Self {
        Self::with_capacity(Self::DEFAULT_CAPACITY)
    }
}

impl PlanCache {
    /// Default bound: generous for steady-state training loops (a handful
    /// of shapes) while capping sweep-style workloads.
    pub const DEFAULT_CAPACITY: usize = 128;

    pub fn new() -> Self {
        Self::default()
    }

    /// A cache holding at most `capacity` plans (min 1).
    pub fn with_capacity(capacity: usize) -> Self {
        Self {
            state: Mutex::new(LruState {
                plans: HashMap::new(),
                tick: 0,
            }),
            capacity: capacity.max(1),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
            evictions: AtomicUsize::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Return the cached plan for this shape, planning (and validating) it
    /// on first use. A hit refreshes the shape's LRU position.
    pub fn get_or_plan(
        &self,
        spec: &ClusterSpec,
        layout: &PoolLayout,
        primitive: Primitive,
        cfg: &CclConfig,
        n_elems: usize,
        dtype: Dtype,
    ) -> Result<ValidPlan> {
        let key = PlanKey::new(primitive, cfg, spec, layout, n_elems, dtype);
        {
            let mut st = self.state.lock().unwrap();
            st.tick += 1;
            let tick = st.tick;
            if let Some((plan, touched)) = st.plans.get_mut(&key) {
                *touched = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Ok(plan.clone());
            }
        }
        // Plan outside the lock: planning can be slow and racing planners
        // produce identical plans, so the first insert simply wins. The
        // insert's vacancy decides hit-vs-miss, keeping the invariant
        // `misses == number of shapes ever inserted` even under concurrent
        // first launches.
        let plan = plan_collective_dtype(primitive, spec, layout, cfg, n_elems, dtype)?;
        let mut st = self.state.lock().unwrap();
        st.tick += 1;
        let tick = st.tick;
        if let Some((existing, touched)) = st.plans.get_mut(&key) {
            *touched = tick;
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(existing.clone());
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        if st.plans.len() >= self.capacity {
            // Evict the least-recently-used shape to stay within bounds.
            let victim = st
                .plans
                .iter()
                .min_by_key(|(_, (_, touched))| *touched)
                .map(|(k, _)| *k);
            if let Some(old) = victim {
                st.plans.remove(&old);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        st.plans.insert(key, (plan.clone(), tick));
        Ok(plan)
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
        }
    }

    /// Number of distinct plans currently cached.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().plans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every cached plan (counters are preserved).
    pub fn clear(&self) {
        self.state.lock().unwrap().plans.clear();
    }
}

impl std::fmt::Debug for PlanCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PlanCache")
            .field("len", &self.len())
            .field("capacity", &self.capacity)
            .field("stats", &self.stats())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn hits_return_the_same_arc_and_count() {
        let spec = ClusterSpec::new(3, 6, 4 << 20);
        let layout = PoolLayout::from_spec(&spec).unwrap();
        let cache = PlanCache::new();
        let cfg = CclVariant::All.config(4);
        let a = cache
            .get_or_plan(&spec, &layout, Primitive::AllGather, &cfg, 3 * 256, Dtype::F32)
            .unwrap();
        let b = cache
            .get_or_plan(&spec, &layout, Primitive::AllGather, &cfg, 3 * 256, Dtype::F32)
            .unwrap();
        assert!(
            Arc::ptr_eq(a.as_arc(), b.as_arc()),
            "second lookup must reuse the plan"
        );
        assert_eq!(cache.stats(), CacheStats { hits: 1, misses: 1, evictions: 0 });
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn dtype_and_shape_are_part_of_the_key() {
        let spec = ClusterSpec::new(3, 6, 4 << 20);
        let layout = PoolLayout::from_spec(&spec).unwrap();
        let cache = PlanCache::new();
        let cfg = CclVariant::All.config(4);
        for (n, d) in [(3 * 256, Dtype::F32), (3 * 256, Dtype::U8), (3 * 512, Dtype::F32)] {
            cache
                .get_or_plan(&spec, &layout, Primitive::AllGather, &cfg, n, d)
                .unwrap();
        }
        assert_eq!(cache.len(), 3);
        assert_eq!(cache.stats().hits, 0);
    }

    #[test]
    fn planning_errors_are_not_cached() {
        let spec = ClusterSpec::new(3, 6, 4 << 20);
        let layout = PoolLayout::from_spec(&spec).unwrap();
        let cache = PlanCache::new();
        let cfg = CclVariant::All.config(8);
        // Not divisible by nranks -> plan error.
        assert!(cache
            .get_or_plan(&spec, &layout, Primitive::AllToAll, &cfg, 1000, Dtype::F32)
            .is_err());
        assert!(cache.is_empty());
        assert_eq!(cache.stats().misses, 0);
    }

    #[test]
    fn key_reconstructs_config() {
        let spec = ClusterSpec::new(3, 6, 4 << 20);
        let layout = PoolLayout::from_spec(&spec).unwrap();
        let cfg = CclVariant::All.config(8).with_root(2);
        let key = PlanKey::new(Primitive::Broadcast, &cfg, &spec, &layout, 1024, Dtype::F16);
        assert_eq!(key.config(), cfg);
        assert_eq!(key.dtype, Dtype::F16);
    }

    #[test]
    fn layout_windows_are_part_of_the_key() {
        // The same shape planned against the even and odd epoch halves must
        // occupy two cache entries: the plans differ (disjoint windows).
        let spec = ClusterSpec::new(3, 6, 4 << 20);
        let layout = PoolLayout::from_spec(&spec).unwrap();
        let [even, odd] = layout.pipeline_halves().unwrap();
        let cfg = CclVariant::All.config(4);
        let k_even = PlanKey::new(Primitive::AllGather, &cfg, &spec, &even, 3 * 256, Dtype::F32);
        let k_odd = PlanKey::new(Primitive::AllGather, &cfg, &spec, &odd, 3 * 256, Dtype::F32);
        assert_ne!(k_even, k_odd);
        let cache = PlanCache::new();
        cache
            .get_or_plan(&spec, &even, Primitive::AllGather, &cfg, 3 * 256, Dtype::F32)
            .unwrap();
        cache
            .get_or_plan(&spec, &odd, Primitive::AllGather, &cfg, 3 * 256, Dtype::F32)
            .unwrap();
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.stats().misses, 2);
        // Steady state: each half hits its own entry.
        cache
            .get_or_plan(&spec, &even, Primitive::AllGather, &cfg, 3 * 256, Dtype::F32)
            .unwrap();
        assert_eq!(cache.stats().hits, 1);
    }

    #[test]
    fn multi_slice_rings_occupy_one_entry_per_slice() {
        // A depth-N ring plans the same shape once per slice window: N
        // entries, N misses, and steady state hits each slice's own entry.
        let spec = ClusterSpec::new(3, 6, 4 << 20);
        let layout = PoolLayout::from_spec(&spec).unwrap();
        let cfg = CclVariant::All.config(4);
        for n_slices in [2usize, 3] {
            let slices = layout.pipeline_slices(n_slices).unwrap();
            let cache = PlanCache::new();
            for shape in [3 * 128usize, 3 * 256] {
                for s in &slices {
                    cache
                        .get_or_plan(&spec, s, Primitive::AllGather, &cfg, shape, Dtype::F32)
                        .unwrap();
                }
            }
            assert_eq!(cache.len(), 2 * n_slices, "ring depth {n_slices}");
            assert_eq!(cache.stats().misses, 2 * n_slices);
            assert_eq!(cache.stats().hits, 0);
            // One steady-state launch train over the ring: all hits.
            for s in &slices {
                cache
                    .get_or_plan(&spec, s, Primitive::AllGather, &cfg, 3 * 128, Dtype::F32)
                    .unwrap();
            }
            assert_eq!(cache.stats().hits, n_slices);
            assert_eq!(cache.stats().misses, 2 * n_slices);
        }
    }

    #[test]
    fn capacity_one_short_of_ring_times_shapes_evicts_the_lru_slice_only() {
        // N slices x S shapes at capacity N*S - 1: the last insert evicts
        // exactly the least-recently-used (slice, shape) entry; every other
        // slice entry of that shape survives.
        let spec = ClusterSpec::new(3, 6, 4 << 20);
        let layout = PoolLayout::from_spec(&spec).unwrap();
        let cfg = CclVariant::All.config(4);
        let slices = layout.pipeline_slices(3).unwrap();
        let shapes = [3 * 128usize, 3 * 256];
        let cache = PlanCache::with_capacity(3 * shapes.len() - 1); // 5
        for shape in shapes {
            for s in &slices {
                cache
                    .get_or_plan(&spec, s, Primitive::AllGather, &cfg, shape, Dtype::F32)
                    .unwrap();
            }
        }
        assert_eq!(cache.len(), 5);
        assert_eq!(
            cache.stats(),
            CacheStats { hits: 0, misses: 6, evictions: 1 },
            "the 6th insert evicts exactly one entry"
        );
        // The victim was the oldest entry: (shape A, slice 0). Every other
        // (shape, slice) entry is still cached — probing them is pure hits
        // (hits never evict), which proves exactly one entry was dropped.
        let before = cache.stats();
        for shape in shapes {
            for s in &slices {
                if shape == shapes[0] && s.db_slot_base == slices[0].db_slot_base {
                    continue;
                }
                cache
                    .get_or_plan(&spec, s, Primitive::AllGather, &cfg, shape, Dtype::F32)
                    .unwrap();
            }
        }
        assert_eq!(
            cache.stats(),
            CacheStats { hits: before.hits + 5, misses: before.misses, evictions: 1 },
            "all five survivors hit; nothing else was evicted"
        );
        // Only the evicted slice replans: one miss (plus the LRU eviction
        // that makes room for it at full capacity).
        cache
            .get_or_plan(&spec, &slices[0], Primitive::AllGather, &cfg, shapes[0], Dtype::F32)
            .unwrap();
        assert_eq!(cache.stats().misses, before.misses + 1);
        assert_eq!(cache.len(), 5);
    }

    #[test]
    fn stats_stay_exact_across_a_mixed_depth_workload() {
        // One cache serving a depth-1 (undivided), depth-2, and depth-3
        // view of the same shape: 1 + 2 + 3 = 6 distinct windows. Replaying
        // the whole workload R more times adds exactly 6*R hits.
        let spec = ClusterSpec::new(3, 6, 4 << 20);
        let layout = PoolLayout::from_spec(&spec).unwrap();
        let cfg = CclVariant::All.config(4);
        let mut views = vec![layout];
        views.extend(layout.pipeline_slices(2).unwrap());
        views.extend(layout.pipeline_slices(3).unwrap());
        assert_eq!(views.len(), 6);
        let cache = PlanCache::new();
        let replay = |cache: &PlanCache| {
            for v in &views {
                cache
                    .get_or_plan(&spec, v, Primitive::AllReduce, &cfg, 3 * 128, Dtype::F32)
                    .unwrap();
            }
        };
        replay(&cache);
        assert_eq!(cache.stats(), CacheStats { hits: 0, misses: 6, evictions: 0 });
        for _ in 0..4 {
            replay(&cache);
        }
        assert_eq!(cache.stats(), CacheStats { hits: 24, misses: 6, evictions: 0 });
        assert_eq!(cache.len(), 6);
    }

    #[test]
    fn lru_capacity_bounds_the_cache_and_counts_evictions() {
        let spec = ClusterSpec::new(3, 6, 4 << 20);
        let layout = PoolLayout::from_spec(&spec).unwrap();
        let cache = PlanCache::with_capacity(2);
        let cfg = CclVariant::All.config(4);
        let plan = |cache: &PlanCache, n: usize| {
            cache
                .get_or_plan(&spec, &layout, Primitive::AllGather, &cfg, n, Dtype::F32)
                .unwrap()
        };
        plan(&cache, 3 * 128); // A
        plan(&cache, 3 * 256); // B
        assert_eq!(cache.len(), 2);
        // Touch A so B becomes the LRU entry, then insert C.
        plan(&cache, 3 * 128);
        plan(&cache, 3 * 512); // C evicts B
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.stats().evictions, 1);
        // A is still cached (hit), B must replan (miss).
        let before = cache.stats();
        plan(&cache, 3 * 128);
        assert_eq!(cache.stats().hits, before.hits + 1);
        plan(&cache, 3 * 256);
        assert_eq!(cache.stats().misses, before.misses + 1);
        assert_eq!(cache.stats().evictions, 2, "re-inserting B evicts the LRU entry");
        // A sweep over many shapes never exceeds capacity.
        for i in 1..=20 {
            plan(&cache, 3 * 1024 + 3 * i);
        }
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.capacity(), 2);
    }
}
