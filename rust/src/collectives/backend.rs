//! The unified backend trait — "one algorithm, two backends" as an API.
//!
//! A [`CollectivePlan`] is a backend-independent program. Everything that
//! can run one implements [`CollectiveBackend`]:
//!
//! - [`crate::exec::Communicator`] executes it for real over the shared
//!   memory pool and reports wall-clock time,
//! - [`crate::sim::fabric::SimFabric`] times it on the calibrated
//!   flow-level fabric and reports virtual time.
//!
//! Benches, examples, the CLI and the FSDP train loop all drive whichever
//! backend they are handed through this one interface instead of matching
//! on the backend type.

use crate::collectives::ops::{CollectivePlan, ValidPlan};
use crate::sim::SimReport;
use crate::tensor::{Tensor, TensorView, TensorViewMut};
use anyhow::{bail, Result};
use std::time::Duration;

/// What running a plan produced: real elapsed time or a virtual-time
/// report. [`ExecOutcome::seconds`] unifies the two for timing-only code.
#[derive(Debug, Clone)]
pub enum ExecOutcome {
    /// Real execution over a pool; data moved, wall-clock measured.
    Executed { wall: Duration },
    /// Virtual-time simulation; no data moved.
    Simulated { report: SimReport },
}

impl ExecOutcome {
    /// Elapsed seconds — wall-clock or virtual, depending on the backend.
    pub fn seconds(&self) -> f64 {
        match self {
            ExecOutcome::Executed { wall } => wall.as_secs_f64(),
            ExecOutcome::Simulated { report } => report.total_time,
        }
    }

    /// Whether the outcome came from a virtual-time backend.
    pub fn is_virtual(&self) -> bool {
        matches!(self, ExecOutcome::Simulated { .. })
    }

    /// The simulator's full report, when the backend was virtual.
    pub fn sim_report(&self) -> Option<&SimReport> {
        match self {
            ExecOutcome::Simulated { report } => Some(report),
            ExecOutcome::Executed { .. } => None,
        }
    }
}

/// A backend that can run a planned collective.
pub trait CollectiveBackend {
    /// Short backend name for logs and tables.
    fn name(&self) -> &'static str;

    /// Virtual backends only *time* plans; they accept empty buffer slices
    /// and never touch caller memory.
    fn is_virtual(&self) -> bool {
        false
    }

    /// Run `plan` with one send and one recv view per rank. Views must
    /// match the plan's dtype and element counts. Virtual backends also
    /// accept `(&[], &mut [])`.
    ///
    /// Only pre-validated [`ValidPlan`]s are accepted: validation happened
    /// when the planner/cache sealed the plan, so steady-state launches
    /// perform no per-launch `validate()` work. Hand-built plans enter
    /// through [`ValidPlan::new`].
    fn run(
        &self,
        plan: &ValidPlan,
        sends: &[TensorView<'_>],
        recvs: &mut [TensorViewMut<'_>],
    ) -> Result<ExecOutcome>;
}

/// Per-rank buffer validation shared by every backend (and available to
/// out-of-crate backend implementations): one send and one recv view per
/// rank, all matching the plan's dtype and Table 2 element counts. Using
/// this keeps the two built-in backends failing identically on the same
/// bad input.
pub fn validate_views(
    plan: &CollectivePlan,
    sends: &[TensorView<'_>],
    recvs: &[TensorViewMut<'_>],
) -> Result<()> {
    if sends.len() != plan.nranks || recvs.len() != plan.nranks {
        bail!("need one send and one recv buffer per rank");
    }
    for (r, s) in sends.iter().enumerate() {
        if s.dtype() != plan.dtype {
            bail!(
                "rank {r} send buffer dtype {} does not match plan dtype {}",
                s.dtype(),
                plan.dtype
            );
        }
        if s.len() < plan.send_elems {
            bail!(
                "rank {r} send buffer too small: {} < {} elems",
                s.len(),
                plan.send_elems
            );
        }
    }
    for (r, d) in recvs.iter().enumerate() {
        if d.dtype() != plan.dtype {
            bail!(
                "rank {r} recv buffer dtype {} does not match plan dtype {}",
                d.dtype(),
                plan.dtype
            );
        }
        if d.len() < plan.recv_elems {
            bail!(
                "rank {r} recv buffer too small: {} < {} elems",
                d.len(),
                plan.recv_elems
            );
        }
    }
    Ok(())
}

/// Run a plan on any backend with freshly allocated zeroed buffers — the
/// shared code path for timing-only runs (benches, sweeps, the CLI's sim
/// mode). Virtual backends get no buffers at all.
pub fn run_with_scratch(
    backend: &dyn CollectiveBackend,
    plan: &ValidPlan,
) -> Result<ExecOutcome> {
    if backend.is_virtual() {
        return backend.run(plan, &[], &mut []);
    }
    let sends: Vec<Tensor> = (0..plan.nranks)
        .map(|_| Tensor::zeros(plan.dtype, plan.send_elems))
        .collect();
    let mut recvs: Vec<Tensor> = (0..plan.nranks)
        .map(|_| Tensor::zeros(plan.dtype, plan.recv_elems))
        .collect();
    let send_views: Vec<TensorView<'_>> = sends.iter().map(Tensor::view).collect();
    let mut recv_views: Vec<TensorViewMut<'_>> = recvs.iter_mut().map(Tensor::view_mut).collect();
    backend.run(plan, &send_views, &mut recv_views)
}
