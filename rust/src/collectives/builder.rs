//! Compiles a (primitive, variant, message size) triple into per-rank
//! operation streams, applying the paper's three mechanisms:
//! placement (§4.3, Eqs. 1–4), chunked overlap (§4.4), and
//! computation-driven doorbell indexing (§4.5, Eq. 2).

use crate::chunking::{effective_chunks, publish_order, split_aligned, DoorbellIndexer};
use crate::collectives::ops::{CollectivePlan, Op, RankPlan, ValidPlan};
use crate::collectives::{CclConfig, CclVariant, Primitive};
use crate::interleave::{self, rotated_peers, rotated_peers_desc, BlockAddr};
use crate::pool::PoolLayout;
use crate::tensor::Dtype;
use crate::topology::ClusterSpec;
use anyhow::{bail, Context, Result};

/// Round a block length up to the uniform placement stride (64 B keeps every
/// block cache-line aligned, and therefore f32-aligned, on every device).
fn stride_of(max_block_len: usize) -> usize {
    max_block_len.div_ceil(64) * 64
}

/// Whether the rank's writes go through the pool at all for this primitive.
struct Ctx<'a> {
    spec: &'a ClusterSpec,
    layout: &'a PoolLayout,
    cfg: &'a CclConfig,
    ix: DoorbellIndexer,
    /// Per-rank message bytes; the §5.4 slicing factor partitions this, and
    /// each block receives its proportional share of chunks.
    msg_bytes: usize,
}

impl<'a> Ctx<'a> {
    /// Place block `data_id` of `writer`. `root_single_writer` selects the
    /// type-1 namespace where only the root produces data (Broadcast,
    /// Scatter) so the naive global id needs no writer term.
    fn place(
        &self,
        writer: usize,
        data_id: usize,
        blocks_per_rank: usize,
        stride: usize,
        root_based: bool,
        root_single_writer: bool,
    ) -> Result<BlockAddr> {
        match self.cfg.variant {
            CclVariant::Naive => {
                let global = if root_single_writer {
                    data_id
                } else {
                    writer * blocks_per_rank + data_id
                };
                interleave::naive(self.layout, global, stride)
            }
            _ if root_based => interleave::type1(self.layout, data_id, stride),
            _ => interleave::type2(
                self.layout,
                self.spec.nranks,
                writer,
                data_id,
                blocks_per_rank,
                stride,
            ),
        }
        .with_context(|| {
            format!(
                "placing block (writer {writer}, data_id {data_id}, stride {stride}) \
                 under {:?}",
                self.cfg.variant
            )
        })
    }

    fn overlapped(&self) -> bool {
        self.cfg.variant == CclVariant::All
    }

    /// Emit the publish side of one block: chunked writes, each followed by
    /// its doorbell ring when overlapping (Listing 3 lines 3–7).
    fn emit_write(
        &self,
        plan: &mut RankPlan,
        addr: BlockAddr,
        src_off: usize,
        len: usize,
        writer: usize,
        data_id: usize,
    ) {
        let chunks = effective_chunks(self.cfg.chunks, len, self.msg_bytes);
        for (ci, ch) in split_aligned(len, chunks).into_iter().enumerate() {
            plan.write_ops.push(Op::Write {
                pool_off: addr.pool_offset + ch.offset,
                src_off: src_off + ch.offset,
                len: ch.len,
            });
            if self.overlapped() {
                plan.write_ops.push(Op::SetDoorbell {
                    db: self.ix.index(writer, data_id, ci),
                });
            }
        }
    }

    /// Emit the retrieve side of one block: per-chunk doorbell wait (when
    /// overlapping) + read or reduce (Listing 3 lines 9–15).
    #[allow(clippy::too_many_arguments)]
    fn emit_read(
        &self,
        plan: &mut RankPlan,
        addr: BlockAddr,
        dst_off: usize,
        len: usize,
        writer: usize,
        data_id: usize,
        reduce: bool,
    ) {
        let chunks = effective_chunks(self.cfg.chunks, len, self.msg_bytes);
        for (ci, ch) in split_aligned(len, chunks).into_iter().enumerate() {
            if self.overlapped() {
                plan.read_ops.push(Op::WaitDoorbell {
                    db: self.ix.index(writer, data_id, ci),
                });
            }
            let pool_off = addr.pool_offset + ch.offset;
            plan.read_ops.push(if reduce {
                Op::Reduce {
                    pool_off,
                    dst_off: dst_off + ch.offset,
                    len: ch.len,
                }
            } else {
                Op::Read {
                    pool_off,
                    dst_off: dst_off + ch.offset,
                    len: ch.len,
                }
            });
        }
    }
}

/// Plan an F32 collective (the common case; see [`plan_collective_dtype`]).
pub fn plan_collective(
    primitive: Primitive,
    spec: &ClusterSpec,
    layout: &PoolLayout,
    cfg: &CclConfig,
    n_elems: usize,
) -> Result<ValidPlan> {
    plan_collective_dtype(primitive, spec, layout, cfg, n_elems, Dtype::F32)
}

/// Plan a collective. `n_elems` is the per-rank message size `N` in
/// elements of `dtype` with Table 2 semantics (so e.g. Scatter's root send
/// buffer is `N × nranks` elements). Any dtype can be planned; reducing
/// primitives additionally need a reduce engine that supports the dtype at
/// execution time (the simulator times any plan).
///
/// Returns a [`ValidPlan`]: the plan is validated here, once, against the
/// layout's pool size, so launches never re-validate. Placement interleaves
/// over the layout's *device window* and doorbells index into its
/// *doorbell window*, which is how `ProcessGroup::split` subgroups share a
/// pool without touching each other's slots or devices.
pub fn plan_collective_dtype(
    primitive: Primitive,
    spec: &ClusterSpec,
    layout: &PoolLayout,
    cfg: &CclConfig,
    n_elems: usize,
    dtype: Dtype,
) -> Result<ValidPlan> {
    spec.validate().map_err(|e| anyhow::anyhow!(e))?;
    if n_elems == 0 {
        bail!("message size must be positive");
    }
    let nr = spec.nranks;
    let nd = layout.device_span;
    if cfg.root >= nr {
        bail!("root {} out of range ({nr} ranks)", cfg.root);
    }
    if matches!(primitive, Primitive::ReduceScatter | Primitive::AllToAll) && n_elems % nr != 0 {
        bail!(
            "{primitive}: message size {n_elems} must be divisible by nranks {nr} \
             (Table 2: each rank exchanges N/nranks)"
        );
    }

    let ix = DoorbellIndexer::new(nr.max(nd), cfg.chunks);
    if ix.slots_needed(nr) > layout.doorbell_slots() {
        bail!(
            "doorbell region too small: need {} slots, have {} \
             (grow ClusterSpec::db_region_size or lower the slicing factor)",
            ix.slots_needed(nr),
            layout.doorbell_slots()
        );
    }

    let n_bytes = n_elems * dtype.size_bytes();
    let ctx = Ctx {
        spec,
        layout,
        cfg,
        ix,
        msg_bytes: n_bytes,
    };
    let mut ranks: Vec<RankPlan> = (0..nr).map(RankPlan::new).collect();
    let root = cfg.root;

    match primitive {
        Primitive::Broadcast => {
            // Root's N bytes partitioned across all devices (§5.2): readers
            // start at staggered pieces so they fan out over the pool.
            let npieces = if cfg.variant == CclVariant::Naive { 1 } else { nd };
            let pieces = split_aligned(n_bytes, npieces);
            let stride = stride_of(pieces.iter().map(|p| p.len).max().unwrap());
            let addrs: Vec<BlockAddr> = pieces
                .iter()
                .enumerate()
                .map(|(b, _)| ctx.place(root, b, pieces.len(), stride, true, true))
                .collect::<Result<_>>()?;
            for (b, p) in pieces.iter().enumerate() {
                ctx.emit_write(&mut ranks[root], addrs[b], p.offset, p.len, root, b);
            }
            ranks[root].read_ops.push(Op::CopyLocal {
                src_off: 0,
                dst_off: 0,
                len: n_bytes,
            });
            let readers: Vec<usize> = (0..nr).filter(|r| *r != root).collect();
            let np = pieces.len();
            for (pos, &r) in readers.iter().enumerate() {
                if cfg.variant == CclVariant::All {
                    // Overlapped retrieval: every reader consumes pieces in
                    // write order, but reader `pos` gates piece j on the
                    // doorbell of piece j+pos — it trails the root's write
                    // frontier by `pos` pieces. At any instant the readers
                    // then occupy *distinct* devices while all chasing the
                    // writer ("varying their initial data-chunk offsets",
                    // §5.2). Readers beyond the piece count saturate the
                    // cap and share — the 12-node degradation of Fig. 10.
                    let lag = pos % np; // readers beyond the piece count share a slot
                    for j in 0..np {
                        let gate = (j + lag).min(np - 1);
                        let cj = effective_chunks(cfg.chunks, pieces[j].len, n_bytes);
                        let cg = effective_chunks(cfg.chunks, pieces[gate].len, n_bytes);
                        for (ci, ch) in
                            split_aligned(pieces[j].len, cj).into_iter().enumerate()
                        {
                            ranks[r].read_ops.push(Op::WaitDoorbell {
                                db: ctx.ix.index(root, gate, ci.min(cg - 1)),
                            });
                            ranks[r].read_ops.push(Op::Read {
                                pool_off: addrs[j].pool_offset + ch.offset,
                                dst_off: pieces[j].offset + ch.offset,
                                len: ch.len,
                            });
                        }
                    }
                } else {
                    // Barrier variants: all pieces are already published;
                    // staggered starts keep concurrent readers on distinct
                    // devices at equal read rates.
                    let start = pos % np;
                    for k in 0..np {
                        let b = (start + k) % np;
                        ctx.emit_read(
                            &mut ranks[r],
                            addrs[b],
                            pieces[b].offset,
                            pieces[b].len,
                            root,
                            b,
                            false,
                        );
                    }
                }
            }
        }

        Primitive::Scatter => {
            // Root sends segment `dst` (N elements) to each dst; segments
            // round-robin over devices (Eq. 1) so readers hit disjoint ones.
            let stride = stride_of(n_bytes);
            for dst in publish_order(nr, root, false) {
                let addr = ctx.place(root, dst, nr, stride, true, true)?;
                ctx.emit_write(&mut ranks[root], addr, dst * n_bytes, n_bytes, root, dst);
            }
            ranks[root].read_ops.push(Op::CopyLocal {
                src_off: root * n_bytes,
                dst_off: 0,
                len: n_bytes,
            });
            for dst in 0..nr {
                if dst == root {
                    continue;
                }
                let addr = ctx.place(root, dst, nr, stride, true, true)?;
                ctx.emit_read(&mut ranks[dst], addr, 0, n_bytes, root, dst, false);
            }
        }

        Primitive::Gather | Primitive::Reduce => {
            // Every non-root rank publishes its N bytes as data_id = rank
            // (device = rank % ND, Eq. 1); the root retrieves rotated.
            let stride = stride_of(n_bytes);
            for src in 0..nr {
                if src == root {
                    continue;
                }
                let addr = ctx.place(src, src, 1, stride, true, false)?;
                ctx.emit_write(&mut ranks[src], addr, 0, n_bytes, src, src);
            }
            let reduce = primitive == Primitive::Reduce;
            ranks[root].read_ops.push(Op::CopyLocal {
                src_off: 0,
                dst_off: if reduce { 0 } else { root * n_bytes },
                len: n_bytes,
            });
            for src in rotated_peers(nr, root) {
                let addr = ctx.place(src, src, 1, stride, true, false)?;
                let dst_off = if reduce { 0 } else { src * n_bytes };
                ctx.emit_read(&mut ranks[root], addr, dst_off, n_bytes, src, src, reduce);
            }
        }

        Primitive::AllGather | Primitive::AllReduce => {
            // Each rank publishes its N bytes once, split over its exclusive
            // device range (Eq. 4); every rank retrieves all peers rotated.
            let nblocks = if cfg.variant == CclVariant::Naive {
                1
            } else {
                (nd / nr).max(1)
            };
            let blocks = split_aligned(n_bytes, nblocks);
            let stride = stride_of(blocks.iter().map(|b| b.len).max().unwrap());
            for r in 0..nr {
                for (b, blk) in blocks.iter().enumerate() {
                    let addr = ctx.place(r, b, blocks.len(), stride, false, false)?;
                    ctx.emit_write(&mut ranks[r], addr, blk.offset, blk.len, r, b);
                }
            }
            let reduce = primitive == Primitive::AllReduce;
            for r in 0..nr {
                ranks[r].read_ops.push(Op::CopyLocal {
                    src_off: 0,
                    dst_off: if reduce { 0 } else { r * n_bytes },
                    len: n_bytes,
                });
                for s in rotated_peers(nr, r) {
                    for (b, blk) in blocks.iter().enumerate() {
                        let addr = ctx.place(s, b, blocks.len(), stride, false, false)?;
                        let dst_off = if reduce {
                            blk.offset
                        } else {
                            s * n_bytes + blk.offset
                        };
                        ctx.emit_read(&mut ranks[r], addr, dst_off, blk.len, s, b, reduce);
                    }
                }
            }
        }

        Primitive::ReduceScatter | Primitive::AllToAll => {
            // Each rank's send buffer holds nranks segments by destination;
            // publish rotated (Fig. 6: rank r starts with dst (r+1)%nr).
            let seg = n_bytes / nr;
            let stride = stride_of(seg);
            for r in 0..nr {
                for dst in publish_order(nr, r, false) {
                    let addr = ctx.place(r, dst, nr, stride, false, false)?;
                    ctx.emit_write(&mut ranks[r], addr, dst * seg, seg, r, dst);
                }
            }
            let reduce = primitive == Primitive::ReduceScatter;
            for r in 0..nr {
                ranks[r].read_ops.push(Op::CopyLocal {
                    src_off: r * seg,
                    dst_off: if reduce { 0 } else { r * seg },
                    len: seg,
                });
                // Consume in descending order: producer r-1 publishes our
                // segment first (see `rotated_peers_desc`).
                for s in rotated_peers_desc(nr, r) {
                    let addr = ctx.place(s, r, nr, stride, false, false)?;
                    let dst_off = if reduce { 0 } else { s * seg };
                    ctx.emit_read(&mut ranks[r], addr, dst_off, seg, s, r, reduce);
                }
            }
        }
    }

    // Naive/Aggregate: a single rendezvous separates the publish phase from
    // the retrieve phase on every stream (§4.4's "straightforward approach").
    if cfg.variant != CclVariant::All {
        for rp in &mut ranks {
            rp.write_ops.push(Op::Barrier);
            rp.read_ops.insert(0, Op::Barrier);
        }
    }

    let plan = CollectivePlan {
        primitive,
        variant: cfg.variant,
        nranks: nr,
        n_elems,
        dtype,
        send_elems: primitive.send_elems(n_elems, nr),
        recv_elems: primitive.recv_elems(n_elems, nr),
        ranks,
    };
    // Debug builds audit the planner's output against the layout view it
    // was planned for (the window-containment half of the static
    // analyzer; sealing below runs the layout-free race/reuse half).
    #[cfg(debug_assertions)]
    {
        let diags = crate::analysis::check_windows(&plan, layout);
        if !diags.is_empty() {
            anyhow::bail!(
                "planner emitted ops outside its layout window (builder bug):\n{}",
                crate::analysis::report(&diags)
            );
        }
    }
    ValidPlan::new(plan, layout.pool_size())
        .context("planner produced an invalid plan (this is a bug in the builder)")
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    fn setup() -> (ClusterSpec, PoolLayout) {
        let spec = ClusterSpec::new(3, 6, 4 << 20);
        let layout = PoolLayout::from_spec(&spec).unwrap();
        (spec, layout)
    }

    fn plan(p: Primitive, v: CclVariant, n: usize) -> ValidPlan {
        let (spec, layout) = setup();
        plan_collective(p, &spec, &layout, &v.config(4), n).unwrap()
    }

    #[test]
    fn every_primitive_and_variant_plans_and_validates() {
        let (spec, layout) = setup();
        for p in Primitive::ALL {
            for v in CclVariant::ALL {
                let pl = plan_collective(p, &spec, &layout, &v.config(4), 3 * 1024).unwrap();
                pl.validate(layout.pool_size())
                    .unwrap_or_else(|e| panic!("{p} {v:?}: {e}"));
            }
        }
    }

    #[test]
    fn all_variant_has_doorbells_not_barriers() {
        let pl = plan(Primitive::AllGather, CclVariant::All, 1024 * 3);
        assert!(pl.ranks.iter().all(|r| !r.write_ops.contains(&Op::Barrier)));
        let has_db = pl.ranks.iter().any(|r| {
            r.write_ops.iter().any(|o| matches!(o, Op::SetDoorbell { .. }))
        });
        assert!(has_db);
    }

    #[test]
    fn naive_and_aggregate_have_one_barrier_per_stream() {
        for v in [CclVariant::Naive, CclVariant::Aggregate] {
            let pl = plan(Primitive::AllToAll, v, 1024 * 3);
            for rp in &pl.ranks {
                assert_eq!(
                    rp.write_ops.iter().filter(|o| matches!(o, Op::Barrier)).count(),
                    1
                );
                assert_eq!(rp.read_ops.first(), Some(&Op::Barrier));
                assert!(!rp
                    .read_ops
                    .iter()
                    .any(|o| matches!(o, Op::WaitDoorbell { .. })));
            }
        }
    }

    #[test]
    fn type2_writers_use_disjoint_devices_under_all() {
        let (_, layout) = setup();
        let pl = plan(Primitive::AllToAll, CclVariant::All, 3 * 4096);
        let mut dev_by_rank: Vec<HashSet<usize>> = vec![HashSet::new(); 3];
        for rp in &pl.ranks {
            for op in &rp.write_ops {
                if let Op::Write { pool_off, .. } = op {
                    dev_by_rank[rp.rank].insert(layout.stacking.device_of(*pool_off));
                }
            }
        }
        for a in 0..3 {
            for b in a + 1..3 {
                assert!(
                    dev_by_rank[a].is_disjoint(&dev_by_rank[b]),
                    "ranks {a} and {b} share write devices: {:?} vs {:?}",
                    dev_by_rank[a],
                    dev_by_rank[b]
                );
            }
        }
    }

    #[test]
    fn naive_converges_on_low_devices() {
        let (_, layout) = setup();
        let pl = plan(Primitive::AllGather, CclVariant::Naive, 3 * 1024);
        let devices: HashSet<usize> = pl
            .ranks
            .iter()
            .flat_map(|rp| rp.write_ops.iter())
            .filter_map(|op| match op {
                Op::Write { pool_off, .. } => Some(layout.stacking.device_of(*pool_off)),
                _ => None,
            })
            .collect();
        // All three 4 KiB messages land on device 0 — the naive hotspot.
        assert_eq!(devices, HashSet::from([0]));
    }

    #[test]
    fn broadcast_spreads_root_data_over_all_devices() {
        let (_, layout) = setup();
        let pl = plan(Primitive::Broadcast, CclVariant::All, 6 * 4096);
        let devices: HashSet<usize> = pl.ranks[0]
            .write_ops
            .iter()
            .filter_map(|op| match op {
                Op::Write { pool_off, .. } => Some(layout.stacking.device_of(*pool_off)),
                _ => None,
            })
            .collect();
        assert_eq!(devices.len(), 6, "root should use all six devices");
    }

    #[test]
    fn reducescatter_requires_divisible_size() {
        let (spec, layout) = setup();
        let err = plan_collective(
            Primitive::ReduceScatter,
            &spec,
            &layout,
            &CclVariant::All.config(8),
            1000, // not divisible by 3
        )
        .unwrap_err();
        assert!(err.to_string().contains("divisible"));
    }

    #[test]
    fn publish_order_starts_at_next_rank() {
        let (_, layout) = setup();
        let pl = plan(Primitive::AllToAll, CclVariant::All, 3 * 4096);
        // Rank 0's first write must target dst 1's segment: src_off = 1*seg.
        let seg = 3 * 4096 * 4 / 3;
        let first = pl.ranks[0]
            .write_ops
            .iter()
            .find_map(|op| match op {
                Op::Write { src_off, .. } => Some(*src_off),
                _ => None,
            })
            .unwrap();
        assert_eq!(first, seg, "Fig. 6: rank 0 publishes for rank 1 first");
        let _ = layout;
    }

    #[test]
    fn doorbell_region_exhaustion_is_an_error() {
        let mut spec = ClusterSpec::new(3, 6, 4 << 20);
        spec.db_region_size = 64 * 8; // 8 slots only
        let layout = PoolLayout::from_spec(&spec).unwrap();
        let err = plan_collective(
            Primitive::AllGather,
            &spec,
            &layout,
            &CclVariant::All.config(64),
            3 * 1024,
        )
        .unwrap_err();
        assert!(err.to_string().contains("doorbell region too small"));
    }

    #[test]
    fn root_parameter_respected() {
        let (spec, layout) = setup();
        let cfg = CclVariant::All.config(2).with_root(2);
        let pl = plan_collective(Primitive::Broadcast, &spec, &layout, &cfg, 3 * 1024).unwrap();
        assert!(pl.ranks[2].pool_bytes_written() > 0);
        assert_eq!(pl.ranks[0].pool_bytes_written(), 0);
        let bad = CclVariant::All.config(2).with_root(7);
        assert!(plan_collective(Primitive::Broadcast, &spec, &layout, &bad, 1024).is_err());
    }

    #[test]
    fn dtype_scales_byte_volumes() {
        let (spec, layout) = setup();
        let cfg = CclVariant::All.config(4);
        let n = 3 * 1024;
        let p32 =
            plan_collective_dtype(Primitive::AllGather, &spec, &layout, &cfg, n, Dtype::F32)
                .unwrap();
        let p8 = plan_collective_dtype(Primitive::AllGather, &spec, &layout, &cfg, n, Dtype::U8)
            .unwrap();
        assert_eq!(p8.dtype, Dtype::U8);
        p8.validate(layout.pool_size()).unwrap();
        // Same element count, a quarter of the bytes on the wire.
        let w32: usize = p32.ranks.iter().map(|r| r.pool_bytes_written()).sum();
        let w8: usize = p8.ranks.iter().map(|r| r.pool_bytes_written()).sum();
        assert_eq!(w32, 4 * w8);
        // Reducing primitives are plan-able for 16-bit dtypes too (the
        // executor's engine decides whether it can reduce them).
        let p16 =
            plan_collective_dtype(Primitive::AllReduce, &spec, &layout, &cfg, n, Dtype::Bf16)
                .unwrap();
        p16.validate(layout.pool_size()).unwrap();
        assert_eq!(p16.elem_bytes(), 2);
    }

    #[test]
    fn windowed_layout_plans_stay_inside_their_windows() {
        // A subgroup view: 2 ranks over devices [3, 6) and doorbell slots
        // [32, 64) of a 6-device pool. Every pool touch and every doorbell
        // the plan emits must stay inside those windows.
        let spec = ClusterSpec::new(2, 3, 4 << 20);
        let full = PoolLayout::new(6, 4 << 20, 4096).unwrap();
        let layout = full
            .with_device_window(3, 3)
            .unwrap()
            .with_doorbell_window(32, 32)
            .unwrap();
        for p in [Primitive::AllGather, Primitive::AllToAll, Primitive::Broadcast] {
            let pl = plan_collective(p, &spec, &layout, &CclVariant::All.config(2), 2 * 1024)
                .unwrap();
            for rp in &pl.ranks {
                for op in rp.write_ops.iter().chain(rp.read_ops.iter()) {
                    match *op {
                        Op::Write { pool_off, len, .. }
                        | Op::Read { pool_off, len, .. }
                        | Op::Reduce { pool_off, len, .. } => {
                            let dev = layout.stacking.device_of(pool_off);
                            assert!((3..6).contains(&dev), "{p}: device {dev} outside window");
                            assert_eq!(
                                layout.stacking.device_of(pool_off + len - 1),
                                dev,
                                "{p}: block straddles devices"
                            );
                        }
                        Op::SetDoorbell { db } | Op::WaitDoorbell { db } => {
                            let abs = layout.doorbell_offset(db).unwrap() / 64;
                            assert!(
                                (32..64).contains(&abs),
                                "{p}: doorbell {db} -> absolute slot {abs} outside window"
                            );
                        }
                        _ => {}
                    }
                }
            }
        }
    }

    #[test]
    fn wire_bytes_match_plan_accounting() {
        for p in Primitive::ALL {
            let pl = plan(p, CclVariant::All, 3 * 4096);
            let planned: usize = pl
                .ranks
                .iter()
                .map(|r| r.pool_bytes_written() + r.pool_bytes_read())
                .sum();
            assert!(planned > 0, "{p} moved no pool bytes");
            // Reads+writes must balance: every written byte is read by at
            // least one rank (broadcast: nr-1 ranks).
            let written: usize = pl.ranks.iter().map(|r| r.pool_bytes_written()).sum();
            let read: usize = pl.ranks.iter().map(|r| r.pool_bytes_read()).sum();
            assert!(read >= written, "{p}: read {read} < written {written}");
        }
    }
}
