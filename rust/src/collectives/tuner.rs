//! Size/topology-aware algorithm autotuning (the `Auto` launch surface).
//!
//! The paper's gains come from picking the right (variant, chunk count)
//! pair per collective and message size (§4–§5): interleaving + chunking
//! wins on large bandwidth-bound transfers, while small latency-critical
//! launches can prefer coarser configurations whose plans carry less
//! doorbell/bookkeeping overhead. Hardcoding one [`CclConfig`] per call
//! site does not survive a sweep over shapes — so the launch surface lets
//! callers opt out of choosing: a config built with [`CclConfig::auto`]
//! resolves through [`tune_decision`] at launch.
//!
//! [`tune_decision`] sweeps [`CclVariant::ALL`] × chunk counts
//! ([`CHUNK_SWEEP`]) through [`SimFabric`]'s virtual-time model — planning
//! one candidate launch per epoch-ring slice and simulating the train at
//! the ring's depth — and picks the candidate with the smallest predicted
//! per-launch time. The sweep is a **pure function** of the cluster spec,
//! the (deterministically derived) pipeline ring, and the launch shape:
//! no wall clock, no RNG, no machine state. Every rank of a pool-mode
//! group therefore resolves the identical decision from its own mapping —
//! the same discipline as the v5 pipeline-depth resolution — and the
//! inputs it depends on (spec fields, ring depth, tuner algorithm
//! version) are exactly the fields fingerprinted by the pool layout hash,
//! so mappers from incompatible builds fail rendezvous instead of running
//! divergent auto-resolved plans.
//!
//! Decisions are memoized in a [`DecisionCache`] (one per
//! communicator/group, beside its `PlanCache`), keyed by [`DecisionKey`]
//! — a [`PlanKey`](crate::collectives::PlanKey) minus the variant fields
//! (`variant`, `chunks`) plus the ring depth the prediction assumed.
//! Candidate planning inside the sweep goes straight through
//! [`plan_collective_dtype`], **never** through a `PlanCache`: tuning a
//! shape must not inflate plan-cache miss counters (the PR 2 invariant
//! `misses == distinct cached shapes` stays intact) nor evict live plans.

use crate::collectives::builder::plan_collective_dtype;
use crate::collectives::ops::{CollectivePlan, ValidPlan};
use crate::collectives::{CclConfig, CclVariant, Primitive};
use crate::pool::PoolLayout;
use crate::sim::fabric::SimFabric;
use crate::tensor::Dtype;
use crate::topology::ClusterSpec;
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Version of the tuning algorithm (sweep space + cost model + tie-break).
/// Folded into the pool layout hash: every mapper of a pool world must
/// resolve `auto` launches identically, so a sweep-space change is a
/// rendezvous-breaking protocol change.
pub const TUNER_ALGO_VERSION: u64 = 1;

/// Chunk counts swept for [`CclVariant::All`] (§5.4 puts the sweet spot at
/// 4–8; 1 and 2 cover the small-message regime where chunking overhead
/// dominates). `Aggregate`/`Naive` are single-chunk by definition.
pub const CHUNK_SWEEP: [usize; 4] = [1, 2, 4, 8];

/// The tuner's candidate space, in sweep order: every `(variant, chunks)`
/// pair [`tune_decision`] evaluates for one launch shape, with `root`
/// applied. Shared by the CLI's fixed-config sweeps and by `ccl analyze`
/// (which audits every candidate the tuner could ever pick).
pub fn candidate_configs(root: usize) -> Vec<CclConfig> {
    let mut out = Vec::new();
    for variant in CclVariant::ALL {
        let chunk_candidates: &[usize] = match variant {
            CclVariant::All => &CHUNK_SWEEP,
            // config() forces chunks = 1 for these; sweeping more would
            // re-evaluate the same candidate.
            CclVariant::Aggregate | CclVariant::Naive => &CHUNK_SWEEP[..1],
        };
        for &chunks in chunk_candidates {
            out.push(variant.config(chunks).with_root(root));
        }
    }
    out
}

/// Everything a tuning decision depends on: a
/// [`PlanKey`](crate::collectives::PlanKey) minus the variant fields
/// (`variant`, `chunks` — those are the tuner's *outputs*), plus the
/// pipeline-ring depth the prediction assumed. The layout window fields
/// are the group's **undivided** plan view; the ring slices are derived
/// from it deterministically.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct DecisionKey {
    pub primitive: Primitive,
    pub root: usize,
    pub nranks: usize,
    pub ndevices: usize,
    pub device_capacity: usize,
    pub db_region_size: usize,
    pub db_slot_base: usize,
    pub db_slot_span: usize,
    pub device_base: usize,
    pub device_span: usize,
    /// Epoch-ring depth (number of slices) the prediction modelled.
    pub ring_depth: usize,
    pub n_elems: usize,
    pub dtype: Dtype,
    /// Pool count the decision was made for: 1 for flat worlds, the
    /// [`PoolSet`](crate::fabric::PoolSet) pool count for hierarchical
    /// ones — the same shape resolved flat and two-level must occupy
    /// distinct cache lines (v9).
    pub npools: usize,
}

impl DecisionKey {
    pub fn new(
        primitive: Primitive,
        root: usize,
        spec: &ClusterSpec,
        layout: &PoolLayout,
        ring_depth: usize,
        n_elems: usize,
        dtype: Dtype,
    ) -> Self {
        Self {
            primitive,
            root,
            nranks: spec.nranks,
            ndevices: spec.ndevices,
            device_capacity: spec.device_capacity,
            db_region_size: spec.db_region_size,
            db_slot_base: layout.db_slot_base,
            db_slot_span: layout.db_slot_span,
            device_base: layout.device_base,
            device_span: layout.device_span,
            ring_depth: ring_depth.max(1),
            n_elems,
            dtype,
            npools: 1,
        }
    }

    /// Key the decision by pool count (hierarchical worlds; flat is 1).
    pub fn with_npools(mut self, npools: usize) -> Self {
        self.npools = npools.max(1);
        self
    }
}

/// A resolved tuning decision: the concrete config an `auto` launch runs
/// with, plus the prediction it was chosen on.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TunedDecision {
    /// The winning config (`TuneMode::Fixed`; `root` preserved from the
    /// request).
    pub cfg: CclConfig,
    /// Sim-predicted virtual seconds per launch for the winner (makespan
    /// of a ring-depth launch train divided by its length).
    pub predicted_secs: f64,
    /// Ring depth the prediction modelled.
    pub ring_depth: usize,
    /// How many (variant, chunks) candidates could be planned for this
    /// shape (the rest were infeasible on the ring's slice windows).
    pub feasible: usize,
}

/// Sim-predicted virtual seconds per launch for one fixed candidate
/// config on this ring: plan one launch per slice (the exact plans a
/// steady-state launch train uses) and simulate the train at the ring's
/// depth. Errors if the shape cannot be planned on some slice.
pub fn predict_launch_secs(
    spec: &ClusterSpec,
    layout: &PoolLayout,
    ring: &[PoolLayout],
    primitive: Primitive,
    cfg: &CclConfig,
    n_elems: usize,
    dtype: Dtype,
) -> Result<f64> {
    let slices: &[PoolLayout] = if ring.is_empty() {
        std::slice::from_ref(layout)
    } else {
        ring
    };
    let depth = slices.len();
    let plans: Vec<ValidPlan> = slices
        .iter()
        .map(|s| plan_collective_dtype(primitive, spec, s, cfg, n_elems, dtype))
        .collect::<Result<_>>()?;
    let refs: Vec<&CollectivePlan> = plans.iter().map(|p| &**p).collect();
    let makespan = SimFabric::new(*layout).simulate_pipelined(&refs, depth)?.total_time;
    Ok(makespan / depth as f64)
}

/// Resolve the best (variant, chunks) pair for one launch shape: sweep
/// [`CclVariant::ALL`] × [`CHUNK_SWEEP`] through the virtual-time model
/// and return the candidate with the smallest predicted per-launch time.
/// Ties keep the earliest candidate in sweep order (`All` before
/// `Aggregate` before `Naive`, small chunk counts first) — a total,
/// deterministic order, so every process resolves alike. Candidates that
/// cannot be planned (the shape does not fit a 1/N slice window) are
/// skipped; if *no* candidate fits, the error reports the last planning
/// failure.
pub fn tune_decision(
    spec: &ClusterSpec,
    layout: &PoolLayout,
    ring: &[PoolLayout],
    primitive: Primitive,
    root: usize,
    n_elems: usize,
    dtype: Dtype,
) -> Result<TunedDecision> {
    let ring_depth = if ring.is_empty() { 1 } else { ring.len() };
    let mut best: Option<(CclConfig, f64)> = None;
    let mut feasible = 0usize;
    let mut last_err = None;
    for cfg in candidate_configs(root) {
        match predict_launch_secs(spec, layout, ring, primitive, &cfg, n_elems, dtype) {
            Ok(secs) => {
                feasible += 1;
                // Strictly-less keeps the first candidate on ties.
                if best.is_none_or(|(_, b)| secs < b) {
                    best = Some((cfg, secs));
                }
            }
            Err(e) => last_err = Some(e),
        }
    }
    match best {
        Some((cfg, predicted_secs)) => Ok(TunedDecision {
            cfg,
            predicted_secs,
            ring_depth,
            feasible,
        }),
        None => match last_err {
            Some(e) => Err(e.context(format!(
                "auto-tuning {primitive} ({n_elems} elems, {dtype}): no candidate \
                 (variant, chunks) pair fits the ring's slice windows"
            ))),
            None => bail!("auto-tuning {primitive}: empty candidate sweep"),
        },
    }
}

struct LruState {
    /// Decision + last-touched tick per shape.
    decisions: HashMap<DecisionKey, (TunedDecision, u64)>,
    /// Monotonic access clock.
    tick: u64,
}

/// Thread-safe, LRU-bounded memo of tuning decisions — the same
/// structure and counter discipline as
/// [`PlanCache`](crate::collectives::PlanCache): the insert's vacancy
/// decides hit-vs-miss (`misses == distinct shapes ever tuned`), the
/// sweep itself runs outside the lock, and racing first resolutions
/// produce identical decisions so the first insert wins.
pub struct DecisionCache {
    state: Mutex<LruState>,
    capacity: usize,
    hits: AtomicUsize,
    misses: AtomicUsize,
    evictions: AtomicUsize,
}

impl Default for DecisionCache {
    fn default() -> Self {
        Self::with_capacity(Self::DEFAULT_CAPACITY)
    }
}

impl DecisionCache {
    /// Same bound as `PlanCache`: generous for steady-state loops, capped
    /// for sweeps.
    pub const DEFAULT_CAPACITY: usize = 128;

    pub fn new() -> Self {
        Self::default()
    }

    /// A cache holding at most `capacity` decisions (min 1).
    pub fn with_capacity(capacity: usize) -> Self {
        Self {
            state: Mutex::new(LruState {
                decisions: HashMap::new(),
                tick: 0,
            }),
            capacity: capacity.max(1),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
            evictions: AtomicUsize::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Return the cached decision for this shape, running the tuning
    /// sweep on first use. A hit refreshes the shape's LRU position.
    #[allow(clippy::too_many_arguments)]
    pub fn get_or_tune(
        &self,
        spec: &ClusterSpec,
        layout: &PoolLayout,
        ring: &[PoolLayout],
        primitive: Primitive,
        root: usize,
        n_elems: usize,
        dtype: Dtype,
    ) -> Result<TunedDecision> {
        let ring_depth = if ring.is_empty() { 1 } else { ring.len() };
        let key = DecisionKey::new(primitive, root, spec, layout, ring_depth, n_elems, dtype);
        self.get_or_tune_keyed(key, || {
            tune_decision(spec, layout, ring, primitive, root, n_elems, dtype)
        })
    }

    /// [`DecisionCache::get_or_tune`] with an explicit key and sweep: the
    /// entry point for decisions whose key carries more than a flat shape
    /// — the hierarchical fabric memoizes its flat-vs-two-level choices
    /// here under pool-count-keyed keys
    /// ([`DecisionKey::with_npools`]). `tune` must be a pure function of
    /// the key so racing resolvers produce identical decisions.
    pub fn get_or_tune_keyed(
        &self,
        key: DecisionKey,
        tune: impl FnOnce() -> Result<TunedDecision>,
    ) -> Result<TunedDecision> {
        {
            let mut st = self.state.lock().unwrap();
            st.tick += 1;
            let tick = st.tick;
            if let Some((d, touched)) = st.decisions.get_mut(&key) {
                *touched = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Ok(*d);
            }
        }
        // Sweep outside the lock (it simulates every candidate); racing
        // resolvers compute identical decisions, so the first insert wins
        // and its vacancy decides hit-vs-miss.
        let d = tune()?;
        let mut st = self.state.lock().unwrap();
        st.tick += 1;
        let tick = st.tick;
        if let Some((existing, touched)) = st.decisions.get_mut(&key) {
            *touched = tick;
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(*existing);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        if st.decisions.len() >= self.capacity {
            let victim = st
                .decisions
                .iter()
                .min_by_key(|(_, (_, touched))| *touched)
                .map(|(k, _)| *k);
            if let Some(old) = victim {
                st.decisions.remove(&old);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        st.decisions.insert(key, (d, tick));
        Ok(d)
    }

    /// Introspect a cached decision without touching the LRU clock or the
    /// hit/miss counters (`None` if this shape was never tuned here).
    pub fn peek(&self, key: &DecisionKey) -> Option<TunedDecision> {
        self.state
            .lock()
            .unwrap()
            .decisions
            .get(key)
            .map(|(d, _)| *d)
    }

    pub fn stats(&self) -> super::CacheStats {
        super::CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
        }
    }

    /// Number of distinct decisions currently cached.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().decisions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every cached decision (counters are preserved).
    pub fn clear(&self) {
        self.state.lock().unwrap().decisions.clear();
    }
}

impl std::fmt::Debug for DecisionCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DecisionCache")
            .field("len", &self.len())
            .field("capacity", &self.capacity)
            .field("stats", &self.stats())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{CacheStats, TuneMode};

    fn paper_setup() -> (ClusterSpec, PoolLayout) {
        let spec = ClusterSpec::new(3, 6, 8 << 20);
        let layout = PoolLayout::from_spec(&spec).unwrap();
        (spec, layout)
    }

    #[test]
    fn decision_beats_or_matches_every_fixed_candidate() {
        // The acceptance bar: the auto choice is never worse than any
        // fixed (variant, chunks) candidate under the same cost model —
        // argmin by construction, pinned here per primitive.
        let (spec, layout) = paper_setup();
        for primitive in Primitive::ALL {
            let n = 3 * 4096;
            let d = tune_decision(&spec, &layout, &[], primitive, 0, n, Dtype::F32).unwrap();
            assert_eq!(d.cfg.mode, TuneMode::Fixed);
            assert!(d.predicted_secs > 0.0);
            for v in CclVariant::ALL {
                for chunks in CHUNK_SWEEP {
                    let cfg = v.config(chunks);
                    let secs = predict_launch_secs(
                        &spec, &layout, &[], primitive, &cfg, n, Dtype::F32,
                    )
                    .unwrap();
                    assert!(
                        d.predicted_secs <= secs,
                        "{primitive}: auto {:?} ({}) predicted {} > fixed {:?} at {}",
                        d.cfg.variant,
                        d.cfg.chunks,
                        d.predicted_secs,
                        v,
                        secs
                    );
                }
            }
        }
    }

    #[test]
    fn resolution_is_deterministic() {
        let (spec, layout) = paper_setup();
        let ring = layout.pipeline_slices(2).unwrap();
        for primitive in [Primitive::AllReduce, Primitive::AllGather, Primitive::Broadcast] {
            let a = tune_decision(&spec, &layout, &ring, primitive, 0, 3 * 2048, Dtype::F32)
                .unwrap();
            let b = tune_decision(&spec, &layout, &ring, primitive, 0, 3 * 2048, Dtype::F32)
                .unwrap();
            assert_eq!(a, b);
            assert_eq!(a.ring_depth, 2);
        }
    }

    #[test]
    fn root_is_preserved_and_keyed() {
        let (spec, layout) = paper_setup();
        let d = tune_decision(&spec, &layout, &[], Primitive::Broadcast, 2, 3 * 512, Dtype::F32)
            .unwrap();
        assert_eq!(d.cfg.root, 2);
        let k0 = DecisionKey::new(Primitive::Broadcast, 0, &spec, &layout, 1, 3 * 512, Dtype::F32);
        let k2 = DecisionKey::new(Primitive::Broadcast, 2, &spec, &layout, 1, 3 * 512, Dtype::F32);
        assert_ne!(k0, k2);
    }

    #[test]
    fn cache_counts_one_miss_per_shape_and_peek_is_free() {
        let (spec, layout) = paper_setup();
        let cache = DecisionCache::new();
        let d1 = cache
            .get_or_tune(&spec, &layout, &[], Primitive::AllGather, 0, 3 * 256, Dtype::F32)
            .unwrap();
        let d2 = cache
            .get_or_tune(&spec, &layout, &[], Primitive::AllGather, 0, 3 * 256, Dtype::F32)
            .unwrap();
        assert_eq!(d1, d2);
        assert_eq!(cache.stats(), CacheStats { hits: 1, misses: 1, evictions: 0 });
        assert_eq!(cache.len(), 1);
        let key =
            DecisionKey::new(Primitive::AllGather, 0, &spec, &layout, 1, 3 * 256, Dtype::F32);
        assert_eq!(cache.peek(&key), Some(d1));
        assert_eq!(
            cache.stats(),
            CacheStats { hits: 1, misses: 1, evictions: 0 },
            "peek must not move the counters"
        );
        assert_eq!(
            cache.peek(&DecisionKey {
                n_elems: 3 * 512,
                ..key
            }),
            None
        );
    }

    #[test]
    fn ring_depth_is_part_of_the_key() {
        let (spec, layout) = paper_setup();
        let ring2 = layout.pipeline_slices(2).unwrap();
        let cache = DecisionCache::new();
        for ring in [&[][..], &ring2[..]] {
            cache
                .get_or_tune(&spec, &layout, ring, Primitive::AllReduce, 0, 3 * 1024, Dtype::F32)
                .unwrap();
        }
        assert_eq!(cache.len(), 2, "depth-1 and depth-2 decisions are distinct shapes");
        assert_eq!(cache.stats().misses, 2);
    }

    #[test]
    fn pool_count_is_part_of_the_key() {
        let (spec, layout) = paper_setup();
        let k1 = DecisionKey::new(Primitive::AllReduce, 0, &spec, &layout, 1, 3 * 256, Dtype::F32);
        let k2 = k1.with_npools(2);
        assert_ne!(k1, k2, "npools must separate otherwise-identical shapes");
        let cache = DecisionCache::new();
        let flat = cache
            .get_or_tune(&spec, &layout, &[], Primitive::AllReduce, 0, 3 * 256, Dtype::F32)
            .unwrap();
        // A hierarchical decision for the same flat shape occupies its own
        // cache line under the pool-count key.
        let hier = cache
            .get_or_tune_keyed(k2, || {
                Ok(TunedDecision { predicted_secs: flat.predicted_secs / 2.0, ..flat })
            })
            .unwrap();
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.peek(&k1), Some(flat));
        assert_eq!(cache.peek(&k2), Some(hier));
        assert_ne!(cache.peek(&k1), cache.peek(&k2));
    }

    #[test]
    fn lru_bound_holds() {
        let (spec, layout) = paper_setup();
        let cache = DecisionCache::with_capacity(2);
        for i in 1..=4usize {
            cache
                .get_or_tune(&spec, &layout, &[], Primitive::AllGather, 0, 3 * 128 * i, Dtype::F32)
                .unwrap();
        }
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.stats(), CacheStats { hits: 0, misses: 4, evictions: 2 });
    }

    #[test]
    fn errors_are_not_cached() {
        let (spec, layout) = paper_setup();
        let cache = DecisionCache::new();
        // Not divisible by nranks -> every candidate fails to plan.
        assert!(cache
            .get_or_tune(&spec, &layout, &[], Primitive::AllToAll, 0, 1000, Dtype::F32)
            .is_err());
        assert!(cache.is_empty());
        assert_eq!(cache.stats().misses, 0);
    }
}
