//! The per-rank operation streams a planned collective compiles to.
//!
//! Mirrors §4.4: each rank owns a `writeStream` and a `readStream`
//! (two CUDA streams in the paper; two threads in [`crate::exec`]).
//! Ordering rules:
//! - ops within a stream execute serially, in order;
//! - across streams/ranks, only doorbells (and the barrier, for the
//!   non-overlapping variants) order operations.

use crate::collectives::{CclVariant, Primitive};
use crate::tensor::Dtype;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Process-wide count of [`CollectivePlan::validate`] invocations.
///
/// Observability only: the v3 launch surface hands out [`ValidPlan`]s so
/// steady-state launches perform **no** per-launch validation, and the
/// build-surface test pins that by watching this counter stay flat across
/// repeated launches of a cached plan.
static VALIDATE_CALLS: AtomicUsize = AtomicUsize::new(0);

/// How many times any plan has been validated in this process.
pub fn validate_calls() -> usize {
    VALIDATE_CALLS.load(Ordering::Relaxed)
}

/// One operation on a rank's stream. All offsets are **bytes**; `src_off`
/// indexes the rank's send buffer, `dst_off` its recv buffer, `pool_off`
/// the shared pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    /// Publish: copy `len` bytes of the send buffer into the pool
    /// (`cudaMemcpyDeviceToHost` in Listing 2).
    Write {
        pool_off: usize,
        src_off: usize,
        len: usize,
    },
    /// Mark a chunk READY and flush (Listing 3 lines 5–7).
    SetDoorbell { db: usize },
    /// Spin until a chunk is READY (Listing 3 lines 9–13).
    WaitDoorbell { db: usize },
    /// Retrieve: copy `len` pool bytes into the recv buffer
    /// (`cudaMemcpyHostToDevice`).
    Read {
        pool_off: usize,
        dst_off: usize,
        len: usize,
    },
    /// Retrieve + accumulate elements into the recv buffer (the
    /// consumer-side reduction; executed by the reduce engine, which may be
    /// the AOT Pallas kernel via PJRT). The element type comes from the
    /// enclosing plan's [`CollectivePlan::dtype`]; engines reject dtypes
    /// they cannot reduce at execution time.
    Reduce {
        pool_off: usize,
        dst_off: usize,
        len: usize,
    },
    /// Local move from the rank's own send buffer to its recv buffer
    /// (a rank's own contribution never goes through the pool).
    CopyLocal {
        src_off: usize,
        dst_off: usize,
        len: usize,
    },
    /// Full-communicator rendezvous (Naive/Aggregate phase separator).
    Barrier,
}

impl Op {
    /// Bytes this op moves through the pool (0 for sync/local ops).
    pub fn pool_bytes(&self) -> usize {
        match self {
            Op::Write { len, .. } | Op::Read { len, .. } | Op::Reduce { len, .. } => *len,
            _ => 0,
        }
    }
}

/// The two streams of one rank.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RankPlan {
    pub rank: usize,
    pub write_ops: Vec<Op>,
    pub read_ops: Vec<Op>,
}

impl RankPlan {
    pub fn new(rank: usize) -> Self {
        Self {
            rank,
            write_ops: Vec::new(),
            read_ops: Vec::new(),
        }
    }

    pub fn pool_bytes_written(&self) -> usize {
        self.write_ops.iter().map(Op::pool_bytes).sum()
    }

    pub fn pool_bytes_read(&self) -> usize {
        self.read_ops.iter().map(Op::pool_bytes).sum()
    }
}

/// A fully planned collective: one `RankPlan` per rank plus metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct CollectivePlan {
    pub primitive: Primitive,
    pub variant: CclVariant,
    pub nranks: usize,
    /// Per-rank message size `N` in elements (Table 2 semantics).
    pub n_elems: usize,
    /// Element type of every buffer this plan touches; all byte offsets in
    /// the op streams are multiples of its size.
    pub dtype: Dtype,
    /// Required send/recv buffer lengths in elements.
    pub send_elems: usize,
    pub recv_elems: usize,
    pub ranks: Vec<RankPlan>,
}

impl CollectivePlan {
    /// Element size in bytes of the plan's dtype.
    pub fn elem_bytes(&self) -> usize {
        self.dtype.size_bytes()
    }

    /// Sanity checks shared by tests and the property harness.
    pub fn validate(&self, pool_size: usize) -> Result<(), String> {
        VALIDATE_CALLS.fetch_add(1, Ordering::Relaxed);
        if self.ranks.len() != self.nranks {
            return Err("plan rank count mismatch".into());
        }
        // Writes from different ranks must never overlap in the pool.
        let mut intervals: Vec<(usize, usize, usize)> = Vec::new();
        for rp in &self.ranks {
            for op in &rp.write_ops {
                if let Op::Write { pool_off, len, .. } = op {
                    if pool_off + len > pool_size {
                        return Err(format!(
                            "rank {} writes [{pool_off}, +{len}) beyond pool {pool_size}",
                            rp.rank
                        ));
                    }
                    intervals.push((*pool_off, pool_off + len, rp.rank));
                }
            }
        }
        intervals.sort_unstable();
        for w in intervals.windows(2) {
            if w[1].0 < w[0].1 {
                return Err(format!(
                    "overlapping pool writes: rank {} [{}..{}) vs rank {} [{}..{})",
                    w[0].2, w[0].0, w[0].1, w[1].2, w[1].0, w[1].1
                ));
            }
        }
        // Every WaitDoorbell must have a matching SetDoorbell somewhere.
        let sets: std::collections::HashSet<usize> = self
            .ranks
            .iter()
            .flat_map(|rp| rp.write_ops.iter())
            .filter_map(|op| match op {
                Op::SetDoorbell { db } => Some(*db),
                _ => None,
            })
            .collect();
        for rp in &self.ranks {
            for op in &rp.read_ops {
                if let Op::WaitDoorbell { db } = op {
                    if !sets.contains(db) {
                        return Err(format!(
                            "rank {} waits on doorbell {db} that nobody rings",
                            rp.rank
                        ));
                    }
                }
            }
        }
        // Barrier discipline: either all streams carry exactly one barrier
        // (Naive/Aggregate) or none do (All).
        let barrier_counts: Vec<usize> = self
            .ranks
            .iter()
            .flat_map(|rp| {
                [
                    rp.write_ops.iter().filter(|o| matches!(o, Op::Barrier)).count(),
                    rp.read_ops.iter().filter(|o| matches!(o, Op::Barrier)).count(),
                ]
            })
            .collect();
        if !(barrier_counts.iter().all(|c| *c == 0) || barrier_counts.iter().all(|c| *c == 1)) {
            return Err("inconsistent barrier placement across streams".into());
        }
        Ok(())
    }

    /// Total bytes all ranks move through the pool.
    pub fn total_pool_bytes(&self) -> usize {
        self.ranks
            .iter()
            .map(|r| r.pool_bytes_written() + r.pool_bytes_read())
            .sum()
    }
}

/// A plan that has passed [`CollectivePlan::validate`] against a concrete
/// pool size — the only thing the launch surface accepts.
///
/// The planner and [`crate::collectives::PlanCache`] hand these out, so
/// validation happens exactly once per planned shape and steady-state
/// launches soundly skip it. Hand-built plans (benches, failure-injection
/// tests) go through [`ValidPlan::new`], which runs the same validation.
///
/// Cloning is cheap: the plan itself is shared behind an `Arc`.
#[derive(Debug, Clone)]
pub struct ValidPlan {
    plan: Arc<CollectivePlan>,
    pool_size: usize,
}

impl ValidPlan {
    /// Validate `plan` against `pool_size` and seal it. This is the single
    /// gate between plan construction and plan execution.
    pub fn new(plan: CollectivePlan, pool_size: usize) -> anyhow::Result<Self> {
        Self::from_arc(Arc::new(plan), pool_size)
    }

    /// [`ValidPlan::new`] for a plan already behind an `Arc`.
    ///
    /// Debug builds additionally run the layout-free half of the static
    /// analyzer ([`crate::analysis::check_plan`]) here, so every plan a
    /// test run seals is audited for data races and doorbell reuse.
    /// Release builds pay nothing — sealing stays exactly one `validate`.
    pub fn from_arc(plan: Arc<CollectivePlan>, pool_size: usize) -> anyhow::Result<Self> {
        plan.validate(pool_size)
            .map_err(|e| anyhow::anyhow!("invalid plan: {e}"))?;
        #[cfg(debug_assertions)]
        {
            let diags = crate::analysis::check_plan(&plan);
            if !diags.is_empty() {
                anyhow::bail!(
                    "static analysis rejected plan:\n{}",
                    crate::analysis::report(&diags)
                );
            }
        }
        Ok(Self { plan, pool_size })
    }

    /// The pool size (bytes) this plan was validated against. Executing it
    /// over any pool at least this large is in-bounds by construction.
    pub fn pool_size(&self) -> usize {
        self.pool_size
    }

    /// The shared underlying plan.
    pub fn as_arc(&self) -> &Arc<CollectivePlan> {
        &self.plan
    }
}

impl std::ops::Deref for ValidPlan {
    type Target = CollectivePlan;

    fn deref(&self) -> &CollectivePlan {
        &self.plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn op_pool_bytes() {
        assert_eq!(
            Op::Write { pool_off: 0, src_off: 0, len: 128 }.pool_bytes(),
            128
        );
        assert_eq!(Op::Barrier.pool_bytes(), 0);
        assert_eq!(Op::SetDoorbell { db: 3 }.pool_bytes(), 0);
        assert_eq!(
            Op::Reduce { pool_off: 0, dst_off: 0, len: 64 }.pool_bytes(),
            64
        );
    }

    #[test]
    fn validate_catches_overlapping_writes() {
        let mut p0 = RankPlan::new(0);
        p0.write_ops.push(Op::Write { pool_off: 100, src_off: 0, len: 64 });
        let mut p1 = RankPlan::new(1);
        p1.write_ops.push(Op::Write { pool_off: 130, src_off: 0, len: 64 });
        let plan = CollectivePlan {
            primitive: Primitive::AllGather,
            variant: CclVariant::All,
            nranks: 2,
            n_elems: 16,
            dtype: Dtype::F32,
            send_elems: 16,
            recv_elems: 32,
            ranks: vec![p0, p1],
        };
        let err = plan.validate(1 << 20).unwrap_err();
        assert!(err.contains("overlapping"));
    }

    #[test]
    fn valid_plan_rejects_invalid_and_derefs() {
        let mut p0 = RankPlan::new(0);
        p0.write_ops.push(Op::Write { pool_off: 0, src_off: 0, len: 64 });
        let plan = CollectivePlan {
            primitive: Primitive::AllGather,
            variant: CclVariant::All,
            nranks: 1,
            n_elems: 16,
            dtype: Dtype::F32,
            send_elems: 16,
            recv_elems: 16,
            ranks: vec![p0],
        };
        // Too small a pool -> rejected at the ValidPlan gate.
        assert!(ValidPlan::new(plan.clone(), 32).is_err());
        let vp = ValidPlan::new(plan, 1 << 20).unwrap();
        assert_eq!(vp.pool_size(), 1 << 20);
        // Deref exposes the plan's fields and methods.
        assert_eq!(vp.nranks, 1);
        assert_eq!(vp.total_pool_bytes(), 64);
        let vp2 = vp.clone();
        assert!(Arc::ptr_eq(vp.as_arc(), vp2.as_arc()), "clone shares the plan");
    }

    #[test]
    fn validate_catches_unmatched_doorbell() {
        let mut p0 = RankPlan::new(0);
        p0.read_ops.push(Op::WaitDoorbell { db: 9 });
        let plan = CollectivePlan {
            primitive: Primitive::Broadcast,
            variant: CclVariant::All,
            nranks: 1,
            n_elems: 4,
            dtype: Dtype::F32,
            send_elems: 4,
            recv_elems: 4,
            ranks: vec![p0],
        };
        let err = plan.validate(1 << 20).unwrap_err();
        assert!(err.contains("nobody rings"));
    }
}
