//! Dtype-carrying tensor buffers — the typed surface every collective entry
//! point takes since the v2 API redesign.
//!
//! Three shapes, mirroring how real CCLs describe device buffers:
//!
//! - [`TensorView`] / [`TensorViewMut`] — borrowed, dtype-tagged views over
//!   caller-owned memory (the `sendbuff`/`recvbuff` + `ncclDataType_t` pair
//!   of an `ncclAllReduce` call),
//! - [`Tensor`] — an owned buffer, used by the nonblocking per-rank handle
//!   API ([`crate::exec::RankComm::begin`]) where the launch outlives the
//!   caller's stack frame.
//!
//! All plan offsets are bytes; the element size of the plan's [`Dtype`] is
//! threaded through the planner's stride math, so any dtype whose size
//! divides the 4-byte chunk alignment works for data-movement collectives.
//! Reductions are engine-dependent: the scalar engine implements `F32` and
//! rejects the rest with a clear error (see
//! [`crate::exec::reduce_engine::ReduceEngine::reduce_into_dtype`]).

use anyhow::{bail, Result};

/// Element type of a collective buffer (the `ncclDataType_t` analogue).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dtype {
    /// 32-bit IEEE float — the only dtype the scalar reduce engine sums.
    F32,
    /// 16-bit IEEE float (payload-only here: movable, not yet reducible).
    F16,
    /// bfloat16 (payload-only: movable, not yet reducible).
    Bf16,
    /// Raw bytes / uint8.
    U8,
}

impl Dtype {
    pub const ALL: [Dtype; 4] = [Dtype::F32, Dtype::F16, Dtype::Bf16, Dtype::U8];

    /// Element size in bytes.
    pub fn size_bytes(&self) -> usize {
        match self {
            Dtype::F32 => 4,
            Dtype::F16 | Dtype::Bf16 => 2,
            Dtype::U8 => 1,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::F16 => "f16",
            Dtype::Bf16 => "bf16",
            Dtype::U8 => "u8",
        }
    }

    pub fn parse(s: &str) -> Result<Dtype> {
        for d in Self::ALL {
            if d.name().eq_ignore_ascii_case(s) {
                return Ok(d);
            }
        }
        bail!("unknown dtype {s:?} (expected one of f32|f16|bf16|u8)")
    }
}

impl std::fmt::Display for Dtype {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Borrowed, dtype-tagged read-only buffer.
#[derive(Debug, Clone, Copy)]
pub struct TensorView<'a> {
    bytes: &'a [u8],
    dtype: Dtype,
}

impl<'a> TensorView<'a> {
    /// Tag a raw byte buffer with a dtype. The length must be a whole
    /// number of elements.
    pub fn from_bytes(bytes: &'a [u8], dtype: Dtype) -> Result<Self> {
        if bytes.len() % dtype.size_bytes() != 0 {
            bail!(
                "buffer of {} bytes is not a whole number of {dtype} elements",
                bytes.len()
            );
        }
        Ok(Self { bytes, dtype })
    }

    /// View an f32 slice (always valid: every f32 has a byte representation
    /// and the alignment requirement only decreases).
    pub fn f32(data: &'a [f32]) -> Self {
        // SAFETY: see above.
        let bytes =
            unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
        Self {
            bytes,
            dtype: Dtype::F32,
        }
    }

    /// View a byte slice as a U8 tensor.
    pub fn u8(data: &'a [u8]) -> Self {
        Self {
            bytes: data,
            dtype: Dtype::U8,
        }
    }

    pub fn dtype(&self) -> Dtype {
        self.dtype
    }

    /// Length in elements.
    pub fn len(&self) -> usize {
        self.bytes.len() / self.dtype.size_bytes()
    }

    pub fn is_empty(&self) -> bool {
        self.bytes.is_empty()
    }

    pub fn as_bytes(&self) -> &'a [u8] {
        self.bytes
    }
}

/// Borrowed, dtype-tagged mutable buffer.
#[derive(Debug)]
pub struct TensorViewMut<'a> {
    bytes: &'a mut [u8],
    dtype: Dtype,
}

impl<'a> TensorViewMut<'a> {
    /// Tag a raw mutable byte buffer with a dtype.
    pub fn from_bytes(bytes: &'a mut [u8], dtype: Dtype) -> Result<Self> {
        if bytes.len() % dtype.size_bytes() != 0 {
            bail!(
                "buffer of {} bytes is not a whole number of {dtype} elements",
                bytes.len()
            );
        }
        Ok(Self { bytes, dtype })
    }

    /// View a mutable f32 slice.
    pub fn f32(data: &'a mut [f32]) -> Self {
        // SAFETY: as for `TensorView::f32`; exclusive access is inherited
        // from the &mut borrow.
        let bytes = unsafe {
            std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, data.len() * 4)
        };
        Self {
            bytes,
            dtype: Dtype::F32,
        }
    }

    /// View a mutable byte slice as a U8 tensor.
    pub fn u8(data: &'a mut [u8]) -> Self {
        Self {
            bytes: data,
            dtype: Dtype::U8,
        }
    }

    pub fn dtype(&self) -> Dtype {
        self.dtype
    }

    /// Length in elements.
    pub fn len(&self) -> usize {
        self.bytes.len() / self.dtype.size_bytes()
    }

    pub fn is_empty(&self) -> bool {
        self.bytes.is_empty()
    }

    pub fn as_bytes(&self) -> &[u8] {
        self.bytes
    }

    pub fn as_bytes_mut(&mut self) -> &mut [u8] {
        self.bytes
    }
}

/// Owned, dtype-tagged buffer (for launches that outlive the caller's
/// frame, e.g. the nonblocking per-rank handle API).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    bytes: Vec<u8>,
    dtype: Dtype,
}

impl Tensor {
    /// Zero-initialized tensor of `n_elems` elements.
    pub fn zeros(dtype: Dtype, n_elems: usize) -> Self {
        Self {
            bytes: vec![0u8; n_elems * dtype.size_bytes()],
            dtype,
        }
    }

    /// Copy an f32 slice into an owned F32 tensor.
    pub fn from_f32(data: &[f32]) -> Self {
        Self {
            bytes: TensorView::f32(data).as_bytes().to_vec(),
            dtype: Dtype::F32,
        }
    }

    /// Copy a byte slice into an owned U8 tensor.
    pub fn from_u8(data: &[u8]) -> Self {
        Self {
            bytes: data.to_vec(),
            dtype: Dtype::U8,
        }
    }

    /// Take ownership of raw bytes under a dtype tag.
    pub fn from_bytes(bytes: Vec<u8>, dtype: Dtype) -> Result<Self> {
        if bytes.len() % dtype.size_bytes() != 0 {
            bail!(
                "buffer of {} bytes is not a whole number of {dtype} elements",
                bytes.len()
            );
        }
        Ok(Self { bytes, dtype })
    }

    pub fn dtype(&self) -> Dtype {
        self.dtype
    }

    /// Length in elements.
    pub fn len(&self) -> usize {
        self.bytes.len() / self.dtype.size_bytes()
    }

    pub fn is_empty(&self) -> bool {
        self.bytes.is_empty()
    }

    pub fn as_bytes(&self) -> &[u8] {
        &self.bytes
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.bytes
    }

    pub fn view(&self) -> TensorView<'_> {
        TensorView {
            bytes: &self.bytes,
            dtype: self.dtype,
        }
    }

    pub fn view_mut(&mut self) -> TensorViewMut<'_> {
        TensorViewMut {
            bytes: &mut self.bytes,
            dtype: self.dtype,
        }
    }

    /// Copy out as f32 values (F32 tensors only).
    pub fn to_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != Dtype::F32 {
            bail!("tensor dtype is {}, not f32", self.dtype);
        }
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|c| f32::from_ne_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

// ---- 16-bit float conversion (no `half` crate offline) -----------------
//
// The scalar reduce engine sums F16/Bf16 by widening each element to f32,
// accumulating, and rounding back on store (round-to-nearest-even, the
// hardware convention). These four conversions are the whole dependency.

/// IEEE binary16 bits -> f32 (exact: every f16 is representable).
pub fn f16_to_f32(bits: u16) -> f32 {
    let sign = ((bits & 0x8000) as u32) << 16;
    let exp = (bits >> 10) & 0x1f;
    let mant = (bits & 0x03ff) as u32;
    match (exp, mant) {
        (0, 0) => f32::from_bits(sign),
        (0, m) => {
            // Subnormal: value = m * 2^-24 (exact in f32).
            let v = (m as f32) * f32::from_bits(0x3380_0000);
            if sign != 0 {
                -v
            } else {
                v
            }
        }
        (0x1f, m) => f32::from_bits(sign | 0x7f80_0000 | (m << 13)),
        (e, m) => f32::from_bits(sign | ((e as u32 - 15 + 127) << 23) | (m << 13)),
    }
}

/// f32 -> IEEE binary16 bits, round-to-nearest-even; overflow saturates to
/// infinity, NaN stays NaN.
pub fn f32_to_f16(v: f32) -> u16 {
    let x = v.to_bits();
    let sign = ((x >> 16) & 0x8000) as u16;
    let exp32 = (x >> 23) & 0xff;
    let mant = x & 0x007f_ffff;
    if exp32 == 0xff {
        // Inf / NaN (force a nonzero mantissa for NaN payloads that would
        // truncate to zero).
        let m = if mant == 0 { 0 } else { 0x0200 | ((mant >> 13) as u16 & 0x03ff) };
        return sign | 0x7c00 | m;
    }
    let exp = exp32 as i32 - 127 + 15;
    if exp >= 0x1f {
        return sign | 0x7c00; // overflow -> inf
    }
    if exp <= 0 {
        if exp < -10 {
            return sign; // underflow -> signed zero
        }
        // Subnormal result: shift the (implicit-bit) mantissa into place
        // with round-to-nearest-even.
        let m = mant | 0x0080_0000;
        let shift = (14 - exp) as u32;
        let lsb = (m >> shift) & 1;
        let rounded = (m + (1 << (shift - 1)) - 1 + lsb) >> shift;
        return sign | rounded as u16;
    }
    // Normal result: RNE on the 13 dropped bits.
    let lsb = (mant >> 13) & 1;
    let m = mant + 0x0fff + lsb;
    if m & 0x0080_0000 != 0 {
        // Mantissa carry bumps the exponent (mantissa becomes zero).
        let exp = exp + 1;
        if exp >= 0x1f {
            return sign | 0x7c00;
        }
        return sign | ((exp as u16) << 10);
    }
    sign | ((exp as u16) << 10) | ((m >> 13) as u16 & 0x03ff)
}

/// bfloat16 bits -> f32 (exact: bf16 is a truncated f32).
pub fn bf16_to_f32(bits: u16) -> f32 {
    f32::from_bits((bits as u32) << 16)
}

/// f32 -> bfloat16 bits, round-to-nearest-even; NaN stays NaN.
pub fn f32_to_bf16(v: f32) -> u16 {
    let x = v.to_bits();
    if v.is_nan() {
        // Keep sign + a quiet, nonzero mantissa.
        return ((x >> 16) as u16) | 0x0040;
    }
    let lsb = (x >> 16) & 1;
    (((x + 0x7fff + lsb) >> 16) & 0xffff) as u16
}

/// Wrap a slice of f32 buffers as one view per rank (migration helper for
/// the ubiquitous `&[Vec<f32>]` call sites).
pub fn views_f32(bufs: &[Vec<f32>]) -> Vec<TensorView<'_>> {
    bufs.iter().map(|b| TensorView::f32(b)).collect()
}

/// Mutable counterpart of [`views_f32`].
pub fn views_f32_mut(bufs: &mut [Vec<f32>]) -> Vec<TensorViewMut<'_>> {
    bufs.iter_mut().map(|b| TensorViewMut::f32(b)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn element_sizes_match_spec() {
        assert_eq!(Dtype::F32.size_bytes(), 4);
        assert_eq!(Dtype::F16.size_bytes(), 2);
        assert_eq!(Dtype::Bf16.size_bytes(), 2);
        assert_eq!(Dtype::U8.size_bytes(), 1);
    }

    #[test]
    fn dtype_parse_round_trips() {
        for d in Dtype::ALL {
            assert_eq!(Dtype::parse(d.name()).unwrap(), d);
            assert_eq!(Dtype::parse(&d.name().to_uppercase()).unwrap(), d);
        }
        assert!(Dtype::parse("f64").is_err());
    }

    #[test]
    fn f32_view_round_trips() {
        let data = [1.0f32, -2.5, 3.25];
        let v = TensorView::f32(&data);
        assert_eq!(v.dtype(), Dtype::F32);
        assert_eq!(v.len(), 3);
        assert_eq!(v.as_bytes().len(), 12);
        let t = Tensor::from_f32(&data);
        assert_eq!(t.to_f32().unwrap(), data.to_vec());
    }

    #[test]
    fn mut_view_writes_through() {
        let mut data = vec![0.0f32; 2];
        {
            let mut v = TensorViewMut::f32(&mut data);
            let b = 7.5f32.to_ne_bytes();
            v.as_bytes_mut()[..4].copy_from_slice(&b);
        }
        assert_eq!(data[0], 7.5);
        assert_eq!(data[1], 0.0);
    }

    #[test]
    fn from_bytes_rejects_ragged_lengths() {
        let b = [0u8; 6];
        assert!(TensorView::from_bytes(&b, Dtype::F32).is_err());
        assert!(TensorView::from_bytes(&b, Dtype::F16).is_ok());
        assert!(TensorView::from_bytes(&b, Dtype::U8).is_ok());
        assert!(Tensor::from_bytes(vec![0u8; 7], Dtype::Bf16).is_err());
    }

    #[test]
    fn f16_known_values_and_round_trips() {
        // Spot values.
        assert_eq!(f32_to_f16(0.0), 0x0000);
        assert_eq!(f32_to_f16(-0.0), 0x8000);
        assert_eq!(f32_to_f16(1.0), 0x3c00);
        assert_eq!(f32_to_f16(-2.0), 0xc000);
        assert_eq!(f32_to_f16(65504.0), 0x7bff, "f16 max");
        assert_eq!(f32_to_f16(65520.0), 0x7c00, "halfway above max rounds to inf (RNE)");
        assert_eq!(f32_to_f16(f32::INFINITY), 0x7c00);
        assert_eq!(f16_to_f32(0x3555), 0.333_251_95, "1/3 in f16");
        // Smallest normal and a subnormal.
        assert_eq!(f16_to_f32(0x0400), 6.103_515_6e-5);
        assert_eq!(f16_to_f32(0x0001), f32::from_bits(0x3380_0000));
        assert!(f16_to_f32(0x7e00).is_nan());
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        // Round-to-nearest-even at the 13-bit boundary: 1 + 2^-11 is
        // exactly halfway between 1.0 and the next f16; even mantissa wins.
        assert_eq!(f32_to_f16(1.0 + 2f32.powi(-11)), 0x3c00);
        assert_eq!(f32_to_f16(1.0 + 3.0 * 2f32.powi(-11)), 0x3c02);
        // Every f16 bit pattern (minus NaNs) survives a round trip.
        for bits in 0..=u16::MAX {
            let f = f16_to_f32(bits);
            if f.is_nan() {
                continue;
            }
            assert_eq!(f32_to_f16(f), bits, "f16 round trip of {bits:#06x}");
        }
    }

    #[test]
    fn bf16_known_values_and_round_trips() {
        assert_eq!(f32_to_bf16(1.0), 0x3f80);
        assert_eq!(f32_to_bf16(-1.5), 0xbfc0);
        assert_eq!(bf16_to_f32(0x4049), 3.140_625, "pi in bf16");
        assert_eq!(f32_to_bf16(f32::INFINITY), 0x7f80);
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
        // RNE: 1 + 2^-8 is halfway between 1.0 and the next bf16.
        assert_eq!(f32_to_bf16(1.0 + 2f32.powi(-8)), 0x3f80);
        assert_eq!(f32_to_bf16(1.0 + 3.0 * 2f32.powi(-8)), 0x3f82);
        // Overflow saturates through the rounding add.
        assert_eq!(f32_to_bf16(f32::from_bits(0x7f7f_ffff)), 0x7f80);
        for bits in 0..=u16::MAX {
            let f = bf16_to_f32(bits);
            if f.is_nan() {
                continue;
            }
            assert_eq!(f32_to_bf16(f), bits, "bf16 round trip of {bits:#06x}");
        }
    }

    #[test]
    fn owned_tensor_views() {
        let mut t = Tensor::zeros(Dtype::U8, 8);
        assert_eq!(t.len(), 8);
        t.view_mut().as_bytes_mut()[3] = 9;
        assert_eq!(t.view().as_bytes()[3], 9);
        assert!(t.to_f32().is_err(), "u8 tensor must refuse f32 export");
        let t16 = Tensor::zeros(Dtype::Bf16, 5);
        assert_eq!(t16.as_bytes().len(), 10);
        assert_eq!(t16.len(), 5);
    }
}
