//! **Figure 11** — sensitivity to the slicing factor (number of data
//! chunks), AllGather at 1 GB (paper §5.4): one chunk is worst (no
//! publication/retrieval overlap), 4–8 chunks is best, very fine slicing
//! pays per-chunk software overhead; the paper reports a ~9% max spread.
//!
//! Run: `cargo bench --bench fig11_sensitivity`

use cxl_ccl::bench_util::{banner, Table};
use cxl_ccl::collectives::builder::plan_collective;
use cxl_ccl::collectives::{run_with_scratch, CclVariant, Primitive};
use cxl_ccl::pool::PoolLayout;
use cxl_ccl::sim::SimFabric;
use cxl_ccl::util::size::fmt_time;

fn main() {
    let msg_bytes: usize = std::env::var("FIG11_MB")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(1024)
        << 20;
    let nranks = 3;
    let n = (msg_bytes / 4 / nranks) * nranks;
    let spec = cxl_ccl::topology::ClusterSpec::new(nranks, 6, (2 * msg_bytes).next_power_of_two());
    let layout = PoolLayout::from_spec(&spec).unwrap();
    let fab = SimFabric::new(layout);

    banner(&format!(
        "Figure 11: AllGather {}MiB, slicing factor sweep (3 nodes, 6 devices)",
        msg_bytes >> 20
    ));
    let t = Table::new(&[10, 12, 14]);
    t.header(&["chunks", "latency", "vs best"]);
    let factors = [1usize, 2, 4, 8, 16, 32, 64];
    let times: Vec<f64> = factors
        .iter()
        .map(|&k| {
            let plan =
                plan_collective(Primitive::AllGather, &spec, &layout, &CclVariant::All.config(k), n)
                    .unwrap();
            run_with_scratch(&fab, &plan).unwrap().seconds()
        })
        .collect();
    let best = times.iter().cloned().fold(f64::MAX, f64::min);
    let worst = times.iter().cloned().fold(0.0, f64::max);
    for (k, time) in factors.iter().zip(&times) {
        t.row(&[
            k.to_string(),
            fmt_time(*time),
            format!("+{:.1}%", (time / best - 1.0) * 100.0),
        ]);
    }
    println!(
        "\nmax spread: {:.1}% (paper: ~9%); worst = single chunk (no overlap), best at 4-8",
        (worst / best - 1.0) * 100.0
    );
}
