//! **§5.5** — the LLM-training case study: FSDP communication (AllGather
//! parameters + ReduceScatter gradients) on the CXL pool vs InfiniBand,
//! plus the interconnect cost comparison.
//!
//! Paper: 1.11× end-to-end speedup over RDMA/IB; interconnect hardware
//! cost 2.75× lower ($16K IB switch vs $5.8K CXL switch).
//!
//! The communication volumes are evaluated at the paper's Llama-3-8B FSDP
//! scale *and* at this repo's runnable presets; end-to-end speedup is
//! reported at the paper's compute/communication mix (H100-class compute,
//! ~35% of step time in communication) since this host's CPU compute would
//! otherwise swamp the fabric difference.
//!
//! Run: `cargo bench --bench llm_case_study`

use cxl_ccl::baseline::{collective_time, IbParams};
use cxl_ccl::bench_util::{banner, Table};
use cxl_ccl::collectives::builder::plan_collective;
use cxl_ccl::collectives::{run_with_scratch, CclVariant, Primitive};
use cxl_ccl::cost;
use cxl_ccl::pool::PoolLayout;
use cxl_ccl::sim::SimFabric;
use cxl_ccl::topology::ClusterSpec;
use cxl_ccl::util::size::{fmt_bytes, fmt_time};

/// FSDP per-step communication for a model of `params` parameters sharded
/// over `nranks`: AllGather(shard) + ReduceScatter(full grad).
fn fsdp_step_comm(params: usize, nranks: usize) -> (f64, f64) {
    let shard = params.div_ceil(nranks);
    let padded = shard * nranks;
    // Virtual capacity: the ReduceScatter of the full (padded) gradient
    // places nranks segment-blocks per rank-device range; size each device
    // for the whole flat tensor so every placement fits (simulation moves
    // no real bytes).
    let dev_cap = (2 * padded * 4 + (64 << 20)).next_power_of_two();
    let spec = ClusterSpec::new(nranks, 6, dev_cap);
    let layout = PoolLayout::from_spec(&spec).unwrap();
    let fab = SimFabric::new(layout);
    let ccl = CclVariant::All.config(8);
    let ag = plan_collective(Primitive::AllGather, &spec, &layout, &ccl, shard).unwrap();
    let rs = plan_collective(Primitive::ReduceScatter, &spec, &layout, &ccl, padded).unwrap();
    let cxl = run_with_scratch(&fab, &ag).unwrap().seconds()
        + run_with_scratch(&fab, &rs).unwrap().seconds();
    let ib = IbParams::default();
    let ibt = collective_time(Primitive::AllGather, shard * 4, nranks, &ib)
        + collective_time(Primitive::ReduceScatter, padded * 4, nranks, &ib);
    (cxl, ibt)
}

fn main() {
    banner("§5.5 LLM training case study: FSDP communication per step");
    let t = Table::new(&[22, 10, 12, 12, 12, 12]);
    t.header(&["model", "ranks", "bytes/rank", "CXL", "IB", "speedup"]);
    let cases: [(&str, usize, usize); 4] = [
        ("tiny (118K)", 4, 118_016),
        ("e2e (10.8M)", 4, 10_785_792),
        ("gpt2-small (124M)", 4, 124_000_000),
        ("llama-3-8B (paper)", 3, 8_030_000_000),
    ];
    let mut paper_speedup = 0.0;
    for (name, nranks, params) in cases {
        let (cxl, ib) = fsdp_step_comm(params, nranks);
        let shard = params.div_ceil(nranks);
        t.row(&[
            name.into(),
            nranks.to_string(),
            fmt_bytes(2 * shard * nranks * 4),
            fmt_time(cxl),
            fmt_time(ib),
            format!("{:.2}x", ib / cxl),
        ]);
        if name.starts_with("llama") {
            paper_speedup = ib / cxl;
        }
    }

    banner("end-to-end step speedup at the paper's compute/comm mix");
    // On the paper's H100 testbed the FSDP step is compute-dominated;
    // with comm ~35% of the IB step, a comm speedup s gives
    // e2e = 1 / (0.65 + 0.35/s).
    let t = Table::new(&[28, 12]);
    t.header(&["comm fraction (IB step)", "e2e speedup"]);
    for frac in [0.25, 0.35, 0.45] {
        let e2e = 1.0 / ((1.0 - frac) + frac / paper_speedup);
        t.row(&[format!("{:.0}%", frac * 100.0), format!("{:.2}x", e2e)]);
    }
    println!("(paper: 1.11x end-to-end)");

    banner("interconnect hardware cost (paper: 2.75x cheaper)");
    let t = Table::new(&[34, 12]);
    t.header(&["component", "USD"]);
    let ibf = cost::infiniband_fabric(3);
    for i in &ibf.items {
        t.row(&[format!("IB: {} x{}", i.name, i.quantity), format!("{:.0}", i.total())]);
    }
    let cxf = cost::cxl_fabric(3, 6, false);
    for i in &cxf.items {
        t.row(&[format!("CXL: {} x{}", i.name, i.quantity), format!("{:.0}", i.total())]);
    }
    println!(
        "\nswitch-only ratio: {:.2}x (paper 2.75x); full-BoM ratio: {:.2}x",
        cost::switch_cost_ratio(),
        ibf.total() / cxf.total()
    );
    println!("\nfor the live training run (loss curve + real pool communication), use:");
    println!("  cargo run --release --example train_fsdp -- --preset e2e --steps 120");
}
