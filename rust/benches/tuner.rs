//! **Tuned vs fixed** — the autotuner's acceptance bench: for every
//! primitive × message size × ring depth cell, resolve the `auto` choice
//! through [`tune_decision`] and compare its sim-predicted per-launch time
//! against every fixed (variant, chunks) candidate swept through the same
//! cost model.
//!
//! Two invariants are asserted per cell (CI runs this as a smoke gate):
//!
//! 1. the auto choice is never worse than the **worst** fixed candidate;
//! 2. the auto choice is within 5% of the **best** fixed candidate
//!    (argmin by construction, so the margin catches cost-model drift
//!    between the sweep and this harness).
//!
//! Run: `cargo bench --bench tuner`
//! Env: `TUNER_MAX_MB` (default 64) caps the size sweep; `BENCH_JSON=1`
//! additionally writes machine-readable `BENCH_tuner.json` (per-cell
//! choice + auto/best/worst predicted latency) for the CI perf trajectory.

use cxl_ccl::bench_util::{banner, write_bench_json, Table};
use cxl_ccl::collectives::tuner::{predict_launch_secs, tune_decision, CHUNK_SWEEP};
use cxl_ccl::collectives::{CclVariant, Primitive};
use cxl_ccl::pool::PoolLayout;
use cxl_ccl::topology::ClusterSpec;
use cxl_ccl::tensor::Dtype;
use cxl_ccl::util::size::{fmt_bytes, fmt_time};

/// One measured cell for the JSON artifact.
struct JsonRow {
    primitive: Primitive,
    size_bytes: usize,
    depth: usize,
    choice: String,
    auto_ns: f64,
    best_fixed_ns: f64,
    worst_fixed_ns: f64,
}

fn write_json(nranks: usize, rows: &[JsonRow]) {
    let rendered: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{{\"primitive\": \"{}\", \"size_bytes\": {}, \"depth\": {}, \
                 \"choice\": \"{}\", \"auto_ns\": {:.1}, \"best_fixed_ns\": {:.1}, \
                 \"worst_fixed_ns\": {:.1}}}",
                r.primitive,
                r.size_bytes,
                r.depth,
                r.choice,
                r.auto_ns,
                r.best_fixed_ns,
                r.worst_fixed_ns
            )
        })
        .collect();
    let meta = [("nranks", nranks.to_string())];
    match write_bench_json("BENCH_tuner.json", "tuner", &meta, &rendered) {
        Ok(()) => println!("\nwrote BENCH_tuner.json ({} rows)", rows.len()),
        Err(e) => eprintln!("\nfailed to write BENCH_tuner.json: {e}"),
    }
}

fn main() {
    let max_mb: usize = std::env::var("TUNER_MAX_MB")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64);
    let emit_json = std::env::var("BENCH_JSON").map(|v| v == "1").unwrap_or(false);
    let nranks = 3;
    let sizes_mb: Vec<usize> = [1, 4, 16, 64].into_iter().filter(|m| *m <= max_mb).collect();
    let depths = [1usize, 2];

    banner("tuned vs fixed: auto resolution against the full fixed sweep (3 ranks)");
    println!("(both sides share the virtual-time cost model; auto must be argmin over it)");

    let mut json_rows: Vec<JsonRow> = Vec::new();
    let mut cells = 0usize;
    for prim in Primitive::ALL {
        banner(&format!("tuner panel: {prim}"));
        let t = Table::new(&[10, 7, 14, 12, 12, 12, 10]);
        t.header(&["size", "depth", "auto choice", "auto", "best fixed", "worst fixed", "margin"]);
        for &mb in &sizes_mb {
            let msg_bytes = mb << 20;
            let n_elems = (msg_bytes / 4 / nranks) * nranks;
            for depth in depths {
                // Same capacity growth as the pipelined run path: a
                // depth-N ring places each launch on a 1/N device window.
                let dev_cap =
                    (depth * nranks * msg_bytes + (8 << 20)).next_power_of_two();
                let spec = ClusterSpec::new(nranks, 6, dev_cap);
                let layout = PoolLayout::from_spec(&spec).expect("layout");
                let ring = if depth > 1 {
                    layout.pipeline_slices(depth).expect("ring")
                } else {
                    Vec::new()
                };
                let d = tune_decision(&spec, &layout, &ring, prim, 0, n_elems, Dtype::F32)
                    .expect("tune");
                let (mut best, mut worst) = (f64::INFINITY, 0.0f64);
                for v in CclVariant::ALL {
                    let chunk_candidates: &[usize] = match v {
                        CclVariant::All => &CHUNK_SWEEP,
                        CclVariant::Aggregate | CclVariant::Naive => &CHUNK_SWEEP[..1],
                    };
                    for &chunks in chunk_candidates {
                        let cfg = v.config(chunks);
                        if let Ok(secs) = predict_launch_secs(
                            &spec, &layout, &ring, prim, &cfg, n_elems, Dtype::F32,
                        ) {
                            best = best.min(secs);
                            worst = worst.max(secs);
                        }
                    }
                }
                assert!(best.is_finite(), "{prim} {mb}MB depth {depth}: no feasible candidate");
                assert!(
                    d.predicted_secs <= worst,
                    "{prim} {mb}MB depth {depth}: auto {} worse than worst fixed {}",
                    d.predicted_secs,
                    worst
                );
                assert!(
                    d.predicted_secs <= best * 1.05,
                    "{prim} {mb}MB depth {depth}: auto {} misses best fixed {} by >5%",
                    d.predicted_secs,
                    best
                );
                cells += 1;
                t.row(&[
                    fmt_bytes(msg_bytes),
                    depth.to_string(),
                    d.cfg.describe(),
                    fmt_time(d.predicted_secs),
                    fmt_time(best),
                    fmt_time(worst),
                    format!("{:.2}x", worst / d.predicted_secs),
                ]);
                if emit_json {
                    json_rows.push(JsonRow {
                        primitive: prim,
                        size_bytes: msg_bytes,
                        depth,
                        choice: d.cfg.describe(),
                        auto_ns: d.predicted_secs * 1e9,
                        best_fixed_ns: best * 1e9,
                        worst_fixed_ns: worst * 1e9,
                    });
                }
            }
        }
    }
    println!(
        "\n{cells} cells: auto matched the best fixed candidate within 5% and never \
         chose worse than the worst"
    );

    if emit_json {
        write_json(nranks, &json_rows);
    }
}
