//! **Figure 9** — overall performance of the eight NCCL primitives:
//! CXL-CCL-All / -Aggregate / -Naive on the CXL pool (virtual-time fabric)
//! vs the RDMA-over-200Gb/s-InfiniBand baseline, message sizes 1 MB–4 GB.
//!
//! Paper headline (averaged over message sizes, CXL-CCL-All vs IB):
//! AllGather 1.34×, Broadcast 1.84×, Gather 1.94×, Scatter 1.07×,
//! AllReduce 1.5× (only 1.05× beyond 256 MB), ReduceScatter 1.43×,
//! Reduce 1.70×, AllToAll 1.53×; RS/Scatter/A2A *lose* to IB at small
//! sizes (cudaMemcpy + sync software overhead, §5.2).
//!
//! Run: `cargo bench --bench fig9_collectives`
//! Env: `FIG9_MAX_MB` (default 4096) caps the sweep.

use cxl_ccl::baseline::{collective_time, IbParams};
use cxl_ccl::bench_util::{banner, Table};
use cxl_ccl::collectives::builder::plan_collective;
use cxl_ccl::collectives::{CclVariant, Primitive};
use cxl_ccl::pool::PoolLayout;
use cxl_ccl::sim::SimFabric;
use cxl_ccl::topology::ClusterSpec;
use cxl_ccl::util::size::{fmt_bytes, fmt_time};
use cxl_ccl::util::stats::geomean;

fn main() {
    let max_mb: usize = std::env::var("FIG9_MAX_MB")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4096);
    // Paper testbed: 3 nodes, 6 devices. The virtual pool is sized to hold
    // the largest message comfortably (simulation moves no real bytes).
    let nranks = 3;
    let sizes_mb: Vec<usize> = [1, 4, 16, 64, 256, 1024, 4096]
        .into_iter()
        .filter(|m| *m <= max_mb)
        .collect();
    let ib = IbParams::default();

    banner("Figure 9: collective latency, CXL-CCL vs InfiniBand (3 nodes, 6 CXL devices)");
    println!("(virtual-time fabric calibrated per paper §3; IB = copy-RDMA pipeline model)");

    let mut summary: Vec<(Primitive, f64)> = Vec::new();
    for prim in Primitive::ALL {
        banner(&format!("Fig 9 panel: {prim}"));
        let t = Table::new(&[10, 12, 12, 12, 12, 12]);
        t.header(&["size", "IB", "naive", "aggregate", "all", "all-vs-IB"]);
        let mut speedups = Vec::new();
        for &mb in &sizes_mb {
            let msg_bytes = mb << 20;
            let n_elems = (msg_bytes / 4 / nranks) * nranks; // divisible for RS/A2A
            // Device capacity: big enough for the largest per-device
            // footprint (AllGather naive worst case: nranks × N on dev 0).
            let dev_cap = (nranks * msg_bytes + (8 << 20)).next_power_of_two();
            let spec = ClusterSpec::new(nranks, 6, dev_cap);
            let layout = PoolLayout::from_spec(&spec).unwrap();
            let fab = SimFabric::new(layout);
            let sim = |v: CclVariant| -> f64 {
                let plan = plan_collective(prim, &spec, &layout, &v.config(8), n_elems)
                    .expect("plan");
                fab.simulate(&plan).expect("simulate").total_time
            };
            let t_naive = sim(CclVariant::Naive);
            let t_agg = sim(CclVariant::Aggregate);
            let t_all = sim(CclVariant::All);
            let t_ib = collective_time(prim, n_elems * 4, nranks, &ib);
            let sp = t_ib / t_all;
            speedups.push(sp);
            t.row(&[
                fmt_bytes(msg_bytes),
                fmt_time(t_ib),
                fmt_time(t_naive),
                fmt_time(t_agg),
                fmt_time(t_all),
                format!("{sp:.2}x"),
            ]);
        }
        let avg = geomean(&speedups);
        println!("average CXL-CCL-All speedup vs IB ({prim}): {avg:.2}x");
        summary.push((prim, avg));
    }

    banner(
        "Fig 9 summary (paper: AG 1.34x, Bcast 1.84x, Gather 1.94x, Scatter 1.07x, AR 1.5x, \
         RS 1.43x, Reduce 1.70x, A2A 1.53x)",
    );
    let t = Table::new(&[16, 14]);
    t.header(&["primitive", "avg speedup"]);
    for (p, s) in &summary {
        t.row(&[p.to_string(), format!("{s:.2}x")]);
    }
}
