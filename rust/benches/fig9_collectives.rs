//! **Figure 9** — overall performance of the eight NCCL primitives:
//! CXL-CCL-All / -Aggregate / -Naive on the CXL pool (virtual-time fabric)
//! vs the RDMA-over-200Gb/s-InfiniBand baseline, message sizes 1 MB–4 GB.
//!
//! Paper headline (averaged over message sizes, CXL-CCL-All vs IB):
//! AllGather 1.34×, Broadcast 1.84×, Gather 1.94×, Scatter 1.07×,
//! AllReduce 1.5× (only 1.05× beyond 256 MB), ReduceScatter 1.43×,
//! Reduce 1.70×, AllToAll 1.53×; RS/Scatter/A2A *lose* to IB at small
//! sizes (cudaMemcpy + sync software overhead, §5.2).
//!
//! Run: `cargo bench --bench fig9_collectives`
//! Env: `FIG9_MAX_MB` (default 4096) caps the sweep; `BENCH_JSON=1`
//! additionally writes machine-readable `BENCH_fig9.json` (per-primitive,
//! per-variant latency + bus bandwidth) for the CI perf trajectory.

use cxl_ccl::baseline::{collective_time, IbParams};
use cxl_ccl::bench_util::{banner, write_bench_json, Table};
use cxl_ccl::collectives::builder::plan_collective;
use cxl_ccl::collectives::{run_with_scratch, CclVariant, Primitive};
use cxl_ccl::pool::PoolLayout;
use cxl_ccl::sim::SimFabric;
use cxl_ccl::topology::ClusterSpec;
use cxl_ccl::util::size::{fmt_bytes, fmt_time};
use cxl_ccl::util::stats::geomean;

/// One measured cell for the JSON artifact.
struct JsonRow {
    primitive: Primitive,
    variant: &'static str,
    size_bytes: usize,
    ns: f64,
    bus_gbps: f64,
}

fn write_json(nranks: usize, rows: &[JsonRow]) {
    let rendered: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{{\"primitive\": \"{}\", \"variant\": \"{}\", \"size_bytes\": {}, \
                 \"ns\": {:.1}, \"bus_gbps\": {:.3}}}",
                r.primitive, r.variant, r.size_bytes, r.ns, r.bus_gbps
            )
        })
        .collect();
    let meta = [("nranks", nranks.to_string())];
    match write_bench_json("BENCH_fig9.json", "fig9_collectives", &meta, &rendered) {
        Ok(()) => println!("\nwrote BENCH_fig9.json ({} rows)", rows.len()),
        Err(e) => eprintln!("\nfailed to write BENCH_fig9.json: {e}"),
    }
}

fn main() {
    let max_mb: usize = std::env::var("FIG9_MAX_MB")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4096);
    let emit_json = std::env::var("BENCH_JSON").map(|v| v == "1").unwrap_or(false);
    // Paper testbed: 3 nodes, 6 devices. The virtual pool is sized to hold
    // the largest message comfortably (simulation moves no real bytes).
    let nranks = 3;
    let sizes_mb: Vec<usize> = [1, 4, 16, 64, 256, 1024, 4096]
        .into_iter()
        .filter(|m| *m <= max_mb)
        .collect();
    let ib = IbParams::default();

    banner("Figure 9: collective latency, CXL-CCL vs InfiniBand (3 nodes, 6 CXL devices)");
    println!("(virtual-time fabric calibrated per paper §3; IB = copy-RDMA pipeline model)");

    let mut summary: Vec<(Primitive, f64)> = Vec::new();
    let mut json_rows: Vec<JsonRow> = Vec::new();
    for prim in Primitive::ALL {
        banner(&format!("Fig 9 panel: {prim}"));
        let t = Table::new(&[10, 12, 12, 12, 12, 12]);
        t.header(&["size", "IB", "naive", "aggregate", "all", "all-vs-IB"]);
        let mut speedups = Vec::new();
        for &mb in &sizes_mb {
            let msg_bytes = mb << 20;
            let n_elems = (msg_bytes / 4 / nranks) * nranks; // divisible for RS/A2A
            // Device capacity: big enough for the largest per-device
            // footprint (AllGather naive worst case: nranks × N on dev 0).
            let dev_cap = (nranks * msg_bytes + (8 << 20)).next_power_of_two();
            let spec = ClusterSpec::new(nranks, 6, dev_cap);
            let layout = PoolLayout::from_spec(&spec).unwrap();
            let fab = SimFabric::new(layout);
            // The fabric is driven through the same `CollectiveBackend`
            // trait as the real executor.
            let mut sim = |v: CclVariant| -> f64 {
                let plan = plan_collective(prim, &spec, &layout, &v.config(8), n_elems)
                    .expect("plan");
                let secs = run_with_scratch(&fab, &plan).expect("simulate").seconds();
                if emit_json {
                    json_rows.push(JsonRow {
                        primitive: prim,
                        variant: v.name(),
                        size_bytes: msg_bytes,
                        ns: secs * 1e9,
                        bus_gbps: prim.bytes_on_wire(n_elems, nranks) as f64 / secs / 1e9,
                    });
                }
                secs
            };
            let t_naive = sim(CclVariant::Naive);
            let t_agg = sim(CclVariant::Aggregate);
            let t_all = sim(CclVariant::All);
            let t_ib = collective_time(prim, n_elems * 4, nranks, &ib);
            if emit_json {
                json_rows.push(JsonRow {
                    primitive: prim,
                    variant: "infiniband-200g",
                    size_bytes: msg_bytes,
                    ns: t_ib * 1e9,
                    bus_gbps: prim.bytes_on_wire(n_elems, nranks) as f64 / t_ib / 1e9,
                });
            }
            let sp = t_ib / t_all;
            speedups.push(sp);
            t.row(&[
                fmt_bytes(msg_bytes),
                fmt_time(t_ib),
                fmt_time(t_naive),
                fmt_time(t_agg),
                fmt_time(t_all),
                format!("{sp:.2}x"),
            ]);
        }
        let avg = geomean(&speedups);
        println!("average CXL-CCL-All speedup vs IB ({prim}): {avg:.2}x");
        summary.push((prim, avg));
    }

    banner(
        "Fig 9 summary (paper: AG 1.34x, Bcast 1.84x, Gather 1.94x, Scatter 1.07x, AR 1.5x, \
         RS 1.43x, Reduce 1.70x, A2A 1.53x)",
    );
    let t = Table::new(&[16, 14]);
    t.header(&["primitive", "avg speedup"]);
    for (p, s) in &summary {
        t.row(&[p.to_string(), format!("{s:.2}x")]);
    }

    if emit_json {
        write_json(nranks, &json_rows);
    }
}
