//! **Serving tier** — the KV-cache acceptance bench: seeded Zipf session
//! streams driven through [`kvcache::serve::run_sim`], which exercises the
//! real paged allocator (every lease CAS, generation stamp, and CLOCK
//! sweep) while scoring each request in virtual time from the measured
//! pool constants.
//!
//! Three invariants are asserted per cell (CI runs this as a smoke gate):
//!
//! 1. accounting is conserved: `hits + misses == requests`;
//! 2. popularity skew shows up: a cache of P pages over S >> P Zipf(~1)
//!    sessions hits well above the uniform ceiling `P/S`;
//! 3. determinism: re-running the first cell with the same seed
//!    reproduces its `json_row()` byte for byte.
//!
//! Run: `cargo bench --bench serve`
//! Env: `SERVE_REQUESTS` (default 1M) sets the per-cell request count;
//! `BENCH_JSON=1` additionally writes `BENCH_serve.json` (one row per
//! cell, fixed formatting) for the CI perf trajectory.

use cxl_ccl::bench_util::{banner, write_bench_json, Table};
use cxl_ccl::kvcache::serve::{run_sim, ServeConfig};
use cxl_ccl::util::size::{fmt_bytes, fmt_time};

fn main() {
    let requests: usize = std::env::var("SERVE_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1 << 20);
    let emit_json = std::env::var("BENCH_JSON").map(|v| v == "1").unwrap_or(false);
    let seed = 0xC0FFEE;

    // (sessions, zipf_s, pages, page_size): head-heavy vs flat streams
    // against small and large caches.
    let cells: &[(usize, f64, usize, usize)] = &[
        (1 << 20, 1.05, 4096, 4096),
        (1 << 20, 0.80, 4096, 4096),
        (1 << 18, 1.20, 1024, 4096),
        (1 << 20, 1.05, 4096, 16384),
    ];

    banner(&format!(
        "serve: Zipf streams over the paged KV arena ({} requests/cell, virtual time)",
        requests
    ));
    let t = Table::new(&[10, 6, 7, 9, 10, 12, 12, 12]);
    t.header(&["sessions", "zipf", "pages", "page", "hit rate", "p50", "p99", "evictions"]);

    let mut rows: Vec<String> = Vec::new();
    let mut first_row: Option<String> = None;
    for &(sessions, zipf_s, pages, page_size) in cells {
        let cfg = ServeConfig { sessions, requests, zipf_s, pages, page_size, seed };
        let r = run_sim(&cfg).expect("serve sweep");
        assert_eq!(r.stats.hits + r.stats.misses, requests, "accounting must be conserved");
        let uniform_ceiling = pages as f64 / sessions as f64;
        assert!(
            r.hit_rate() > 2.0 * uniform_ceiling,
            "zipf({zipf_s}) hit rate {:.4} does not beat 2x the uniform ceiling {:.4}",
            r.hit_rate(),
            uniform_ceiling
        );
        assert!(r.stats.evictions > 0, "a {pages}-page cache must evict under this stream");
        t.row(&[
            sessions.to_string(),
            format!("{zipf_s:.2}"),
            pages.to_string(),
            fmt_bytes(page_size),
            format!("{:.2}%", r.hit_rate() * 100.0),
            fmt_time(r.p50_s),
            fmt_time(r.p99_s),
            r.stats.evictions.to_string(),
        ]);
        if first_row.is_none() {
            first_row = Some(r.json_row());
        }
        rows.push(r.json_row());
    }

    // Determinism gate: the first cell re-run with the same seed must
    // reproduce its row byte for byte — the property CI's double-run
    // BENCH_serve.json diff relies on.
    let (sessions, zipf_s, pages, page_size) = cells[0];
    let again = run_sim(&ServeConfig { sessions, requests, zipf_s, pages, page_size, seed })
        .expect("serve replay");
    assert_eq!(
        first_row.as_deref(),
        Some(again.json_row().as_str()),
        "same seed must reproduce the report byte for byte"
    );
    println!("\n{} cells swept; seed replay reproduced cell 0 exactly", cells.len());

    if emit_json {
        let meta = [("requests", requests.to_string()), ("seed", seed.to_string())];
        match write_bench_json("BENCH_serve.json", "serve", &meta, &rows) {
            Ok(()) => println!("wrote BENCH_serve.json ({} rows)", rows.len()),
            Err(e) => eprintln!("failed to write BENCH_serve.json: {e}"),
        }
    }
}
