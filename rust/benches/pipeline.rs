//! Cross-launch pipelining bench: makespan of K steady-state launches at
//! pipeline depths 1 (serialized), 2 (double-buffered) and 4 (four-slice
//! epoch ring), wall-clock over the real shm executor and virtual-time on
//! the calibrated fabric.
//!
//! Run: `cargo bench --bench pipeline`
//! Env: `PIPE_LAUNCHES` (default 8), `PIPE_MB` per-rank MiB (default 4),
//!      `PIPE_DEPTHS` comma-separated depth sweep (default "1,2,4"),
//!      `BENCH_JSON=1` to also emit `BENCH_pipeline.json`.

use cxl_ccl::bench_util::{banner, write_bench_json, Table};
use cxl_ccl::collectives::builder::plan_collective;
use cxl_ccl::collectives::{CclConfig, CclVariant, CollectivePlan, Primitive, ValidPlan};
use cxl_ccl::group::{Bootstrap, CollectiveFuture, CommWorld};
use cxl_ccl::pool::PoolLayout;
use cxl_ccl::sim::SimFabric;
use cxl_ccl::tensor::{Dtype, Tensor};
use cxl_ccl::topology::ClusterSpec;
use cxl_ccl::util::size::fmt_time;
use std::collections::VecDeque;
use std::time::Instant;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_depths() -> Vec<usize> {
    std::env::var("PIPE_DEPTHS")
        .ok()
        .map(|v| v.split(',').filter_map(|d| d.trim().parse().ok()).collect())
        .filter(|v: &Vec<usize>| !v.is_empty())
        .unwrap_or_else(|| vec![1, 2, 4])
}

/// Issue one AllGather launch train round (every rank's part) on `pg`.
fn issue_round<'g>(
    pg: &'g cxl_ccl::group::ProcessGroup,
    cfg: &CclConfig,
    sends: &[Tensor],
    n: usize,
) -> anyhow::Result<Vec<CollectiveFuture<'g>>> {
    (0..sends.len())
        .map(|r| {
            pg.collective_rank(
                r,
                Primitive::AllGather,
                cfg,
                n,
                sends[r].clone(),
                Tensor::zeros(Dtype::F32, n * sends.len()),
            )
        })
        .collect()
}

/// Wall-clock makespan of `k` AllGather launches over a fresh thread-local
/// world bootstrapped with a `depth`-slice epoch ring. In flight launches
/// are bounded to `depth`, mirroring the CLI runner.
fn real_makespan(spec: &ClusterSpec, n: usize, k: usize, depth: usize) -> anyhow::Result<f64> {
    let nr = spec.nranks;
    let boot = Bootstrap::thread_local(spec.clone()).with_pipeline_depth(depth);
    let pg = CommWorld::init(boot, 0, nr)?;
    anyhow::ensure!(
        pg.pipeline_ring().len() == depth,
        "bench world cannot ring {depth} deep (got {})",
        pg.pipeline_ring().len()
    );
    let cfg = CclVariant::All.config(8);
    let sends: Vec<Tensor> = (0..nr).map(|r| Tensor::from_f32(&vec![r as f32; n])).collect();
    // Warm every slice's plan cache entry so the measured loop never plans.
    for _ in 0..depth {
        for f in issue_round(&pg, &cfg, &sends, n)? {
            f.wait()?;
        }
    }
    let t0 = Instant::now();
    let mut in_flight: VecDeque<Vec<CollectiveFuture<'_>>> = VecDeque::with_capacity(depth + 1);
    for _ in 0..k {
        in_flight.push_back(issue_round(&pg, &cfg, &sends, n)?);
        while in_flight.len() > depth {
            for f in in_flight.pop_front().unwrap() {
                f.wait()?;
            }
        }
    }
    while let Some(futs) = in_flight.pop_front() {
        for f in futs {
            f.wait()?;
        }
    }
    pg.flush()?;
    Ok(t0.elapsed().as_secs_f64())
}

fn main() -> anyhow::Result<()> {
    let k = env_usize("PIPE_LAUNCHES", 8);
    let mb = env_usize("PIPE_MB", 4);
    let depths = env_depths();
    let max_depth = depths.iter().copied().max().unwrap_or(1);
    let nranks = 3usize;
    let n = mb * (1 << 20) / 4; // f32 elems per rank
    // Deepest ring shrinks the per-launch device window the most; size the
    // devices so every depth in the sweep places its plans.
    let dev_cap = ((nranks * n * 4 * max_depth) + (8 << 20)).next_power_of_two();
    let spec = ClusterSpec::new(nranks, 6, dev_cap);
    banner(&format!(
        "cross-launch pipelining: {k} x AllGather, {mb} MiB per rank, {nranks} ranks, \
         depths {depths:?}"
    ));

    let layout = PoolLayout::from_spec(&spec)?;
    let fab = SimFabric::new(layout);
    // Depth-1 virtual-time baseline for the speedup column, computed
    // explicitly so the column stays meaningful whatever PIPE_DEPTHS says.
    let base_plan = plan_collective(
        Primitive::AllGather,
        &spec,
        &layout,
        &CclVariant::All.config(8),
        n,
    )?;
    let base_refs: Vec<&CollectivePlan> = (0..k).map(|_| &*base_plan).collect();
    let sim_serial = fab.simulate_pipelined(&base_refs, 1)?.total_time;
    let t = Table::new(&[8, 16, 16, 10]);
    t.header(&["depth", "real makespan", "sim makespan", "sim x vs d1"]);
    let mut json_rows = Vec::with_capacity(depths.len());
    for &depth in &depths {
        // Virtual time: each launch planned on the epoch slice it runs on.
        let slices = layout.pipeline_slices(depth)?;
        let plans: Vec<ValidPlan> = (0..k)
            .map(|i| {
                plan_collective(
                    Primitive::AllGather,
                    &spec,
                    &slices[i % depth],
                    &CclVariant::All.config(8),
                    n,
                )
            })
            .collect::<anyhow::Result<_>>()?;
        let refs: Vec<&CollectivePlan> = plans.iter().map(|p| &**p).collect();
        let sim = fab.simulate_pipelined(&refs, depth)?.total_time;
        let real = real_makespan(&spec, n, k, depth)?;
        t.row(&[
            depth.to_string(),
            fmt_time(real),
            fmt_time(sim),
            format!("{:.2}", sim_serial / sim),
        ]);
        json_rows.push(format!(
            "{{\"depth\": {depth}, \"real_makespan_s\": {real:.6}, \
             \"sim_makespan_s\": {sim:.9}}}"
        ));
    }

    if std::env::var("BENCH_JSON").as_deref() == Ok("1") {
        write_bench_json(
            "BENCH_pipeline.json",
            "pipeline",
            &[
                ("nranks", nranks.to_string()),
                ("launches", k.to_string()),
                ("mb_per_rank", mb.to_string()),
                ("depths", format!("{depths:?}")),
            ],
            &json_rows,
        )?;
        println!("wrote BENCH_pipeline.json");
    }
    Ok(())
}
