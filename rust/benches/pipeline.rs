//! Cross-launch pipelining bench: makespan of K steady-state launches at
//! pipeline depth 1 (serialized) vs depth 2 (double-buffered epoch
//! halves), wall-clock over the real shm executor and virtual-time on the
//! calibrated fabric.
//!
//! Run: `cargo bench --bench pipeline`
//! Env: `PIPE_LAUNCHES` (default 8), `PIPE_MB` per-rank MiB (default 4),
//!      `BENCH_JSON=1` to also emit `BENCH_pipeline.json`.

use cxl_ccl::bench_util::{banner, write_bench_json, Table};
use cxl_ccl::collectives::builder::plan_collective;
use cxl_ccl::collectives::{CclConfig, CollectivePlan, Primitive, ValidPlan};
use cxl_ccl::group::{Bootstrap, CollectiveFuture, CommWorld};
use cxl_ccl::pool::PoolLayout;
use cxl_ccl::sim::SimFabric;
use cxl_ccl::tensor::{Dtype, Tensor};
use cxl_ccl::topology::ClusterSpec;
use cxl_ccl::util::size::fmt_time;
use std::time::Instant;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Wall-clock makespan of `k` AllGather launches at `depth` over a fresh
/// thread-local world.
fn real_makespan(spec: &ClusterSpec, n: usize, k: usize, depth: usize) -> anyhow::Result<f64> {
    let nr = spec.nranks;
    let pg = CommWorld::init(Bootstrap::thread_local(spec.clone()), 0, nr)?
        .with_pipeline_depth(depth)?;
    let cfg = CclConfig::default_all();
    let sends: Vec<Tensor> = (0..nr).map(|r| Tensor::from_f32(&vec![r as f32; n])).collect();
    // Warm the per-half plan caches so the measured loop never plans.
    for _ in 0..2 {
        let futs: Vec<CollectiveFuture<'_>> = (0..nr)
            .map(|r| {
                pg.collective_rank(
                    r,
                    Primitive::AllGather,
                    &cfg,
                    n,
                    sends[r].clone(),
                    Tensor::zeros(Dtype::F32, n * nr),
                )
            })
            .collect::<anyhow::Result<_>>()?;
        for f in futs {
            f.wait()?;
        }
    }
    let t0 = Instant::now();
    let mut all: Vec<Vec<CollectiveFuture<'_>>> = Vec::with_capacity(k);
    for _ in 0..k {
        let futs: Vec<CollectiveFuture<'_>> = (0..nr)
            .map(|r| {
                pg.collective_rank(
                    r,
                    Primitive::AllGather,
                    &cfg,
                    n,
                    sends[r].clone(),
                    Tensor::zeros(Dtype::F32, n * nr),
                )
            })
            .collect::<anyhow::Result<_>>()?;
        all.push(futs);
    }
    for futs in all {
        for f in futs {
            f.wait()?;
        }
    }
    pg.flush()?;
    Ok(t0.elapsed().as_secs_f64())
}

fn main() -> anyhow::Result<()> {
    let k = env_usize("PIPE_LAUNCHES", 8);
    let mb = env_usize("PIPE_MB", 4);
    let nranks = 3usize;
    let n = mb * (1 << 20) / 4; // f32 elems per rank
    let dev_cap = ((nranks * n * 4 * 2) + (8 << 20)).next_power_of_two();
    let spec = ClusterSpec::new(nranks, 6, dev_cap);
    banner(&format!(
        "cross-launch pipelining: {k} x AllGather, {mb} MiB per rank, {nranks} ranks"
    ));

    // Virtual time: each launch planned on the epoch half it runs on.
    let layout = PoolLayout::from_spec(&spec)?;
    let halves = layout.pipeline_halves()?;
    let plans: Vec<ValidPlan> = (0..k)
        .map(|i| {
            plan_collective(
                Primitive::AllGather,
                &spec,
                &halves[i % 2],
                &CclConfig::default_all(),
                n,
            )
        })
        .collect::<anyhow::Result<_>>()?;
    let refs: Vec<&CollectivePlan> = plans.iter().map(|p| &**p).collect();
    let fab = SimFabric::new(layout);
    let sim_d1 = fab.simulate_pipelined(&refs, 1)?.total_time;
    let sim_d2 = fab.simulate_pipelined(&refs, 2)?.total_time;

    // Wall clock over the real executor.
    let real_d1 = real_makespan(&spec, n, k, 1)?;
    let real_d2 = real_makespan(&spec, n, k, 2)?;

    let t = Table::new(&[8, 16, 16, 10]);
    t.header(&["depth", "real makespan", "sim makespan", "sim x"]);
    t.row(&[
        "1".into(),
        fmt_time(real_d1),
        fmt_time(sim_d1),
        "1.00".into(),
    ]);
    t.row(&[
        "2".into(),
        fmt_time(real_d2),
        fmt_time(sim_d2),
        format!("{:.2}", sim_d1 / sim_d2),
    ]);
    println!(
        "wall-clock speedup {:.2}x | virtual-time speedup {:.2}x",
        real_d1 / real_d2,
        sim_d1 / sim_d2
    );

    if std::env::var("BENCH_JSON").as_deref() == Ok("1") {
        write_bench_json(
            "BENCH_pipeline.json",
            "pipeline",
            &[
                ("nranks", nranks.to_string()),
                ("launches", k.to_string()),
                ("mb_per_rank", mb.to_string()),
            ],
            &[
                format!(
                    "{{\"depth\": 1, \"real_makespan_s\": {real_d1:.6}, \
                     \"sim_makespan_s\": {sim_d1:.9}}}"
                ),
                format!(
                    "{{\"depth\": 2, \"real_makespan_s\": {real_d2:.6}, \
                     \"sim_makespan_s\": {sim_d2:.9}}}"
                ),
            ],
        )?;
        println!("wrote BENCH_pipeline.json");
    }
    Ok(())
}
