//! **Figure 10** — scalability: 3 → 6 → 12 nodes over the same six-device
//! pool (the paper's own emulator methodology, §5.3), for the four
//! representative primitives, 128 MB–4 GB.
//!
//! Paper shapes to reproduce:
//! - AllReduce: 2.1–3.0× at 6 nodes, 8.7–12.2× at 12 (reads grow with
//!   ranks and all twelve nodes contend on six devices); NCCL/IB ring
//!   scales better.
//! - Broadcast: 1.26–1.40× at 6 nodes, ~2.5× at 12; ~1.54× faster than IB
//!   on average across all cases.
//! - AllToAll: total traffic is constant in nranks, so growth comes from
//!   contention only: 1.11–1.43× at 6, 1.44–1.83× at 12.
//! - AllGather (4th representative): traffic grows like AllReduce without
//!   the reduction.
//!
//! The v9 panel extends the sweep across **pool counts**: the same
//! message over a flat world (P×L ranks contending on one chassis's six
//! devices) vs the two-level fabric (P pools of L ranks, each on its own
//! six devices, leaders exchanging over the network), decided through
//! [`fabric::tune_fabric`] — the same npools-keyed tuner the launch
//! surface uses.
//!
//! Run: `cargo bench --bench fig10_scalability`
//! Env: `FIG10_MAX_MB` (default 4096); `BENCH_JSON=1` additionally writes
//! machine-readable `BENCH_multipool.json` (per pool count and size:
//! flat vs hierarchical virtual time, split by level) for the CI perf
//! trajectory.

use cxl_ccl::baseline::{collective_time, IbParams};
use cxl_ccl::bench_util::{banner, write_bench_json, Table};
use cxl_ccl::collectives::builder::plan_collective;
use cxl_ccl::collectives::tuner::DecisionCache;
use cxl_ccl::collectives::{run_with_scratch, CclVariant, Primitive};
use cxl_ccl::fabric::{self, PoolSet};
use cxl_ccl::pool::PoolLayout;
use cxl_ccl::sim::SimFabric;
use cxl_ccl::tensor::Dtype;
use cxl_ccl::topology::ClusterSpec;
use cxl_ccl::util::size::{fmt_bytes, fmt_time};

fn sim_time(p: Primitive, nranks: usize, msg_bytes: usize) -> f64 {
    let n = (msg_bytes / 4 / nranks).max(1) * nranks;
    // Virtual capacity sized for the worst per-device footprint.
    let dev_cap = ((nranks * msg_bytes) / 2 + (64 << 20)).next_power_of_two();
    let spec = ClusterSpec::new(nranks, 6, dev_cap);
    let layout = PoolLayout::from_spec(&spec).unwrap();
    let fab = SimFabric::new(layout);
    let plan = plan_collective(p, &spec, &layout, &CclVariant::All.config(8), n).unwrap();
    run_with_scratch(&fab, &plan).unwrap().seconds()
}

fn main() {
    let max_mb: usize = std::env::var("FIG10_MAX_MB")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4096);
    let sizes_mb: Vec<usize> = [128, 512, 1024, 4096]
        .into_iter()
        .filter(|m| *m <= max_mb)
        .collect();
    let ib = IbParams::default();
    let prims = [
        Primitive::AllReduce,
        Primitive::Broadcast,
        Primitive::AllToAll,
        Primitive::AllGather,
    ];

    for p in prims {
        banner(&format!("Fig 10 panel: {p} (6 CXL devices throughout)"));
        let t = Table::new(&[10, 12, 12, 12, 12, 12, 12]);
        t.header(&[
            "size", "cxl@3", "cxl@6", "cxl@12", "x6/x3", "x12/x3", "IB@12",
        ]);
        for &mb in &sizes_mb {
            let bytes = mb << 20;
            let t3 = sim_time(p, 3, bytes);
            let t6 = sim_time(p, 6, bytes);
            let t12 = sim_time(p, 12, bytes);
            let ib12 = collective_time(p, ((bytes / 4 / 12) * 12) * 4, 12, &ib);
            t.row(&[
                fmt_bytes(bytes),
                fmt_time(t3),
                fmt_time(t6),
                fmt_time(t12),
                format!("{:.2}x", t6 / t3),
                format!("{:.2}x", t12 / t3),
                fmt_time(ib12),
            ]);
        }
        match p {
            Primitive::AllReduce => println!(
                "(paper: 2.1-3.0x at 6 nodes, 8.7-12.2x at 12; IB ring reuses partial\n \
                 reductions and scales better — compare cxl@12 vs IB@12)"
            ),
            Primitive::Broadcast => {
                println!("(paper: 1.26-1.40x at 6 nodes, ~2.5x at 12; ~1.54x vs IB on average)")
            }
            Primitive::AllToAll => {
                println!("(paper: 1.11-1.43x at 6 nodes, 1.44-1.83x at 12 — contention only)")
            }
            _ => {}
        }
    }

    multipool_sweep(&sizes_mb, &ib);
}

/// The v9 pool-count sweep: flat vs two-level at 2 and 4 pools of 4
/// ranks, through the npools-keyed fabric tuner. Emits
/// `BENCH_multipool.json` under `BENCH_JSON=1` and hard-asserts the
/// acceptance shape — hierarchical AllReduce beats flat at every pool
/// count for these bandwidth-bound sizes.
fn multipool_sweep(sizes_mb: &[usize], ib: &IbParams) {
    let emit_json = std::env::var("BENCH_JSON").map(|v| v == "1").unwrap_or(false);
    let per_pool = 4;
    let cache = DecisionCache::new();
    let mut rows: Vec<String> = Vec::new();
    for p in [Primitive::AllReduce, Primitive::AllGather] {
        banner(&format!(
            "Fig 10 (v9 panel): {p} — flat world vs two-level fabric, {per_pool} ranks/pool"
        ));
        let t = Table::new(&[10, 7, 7, 12, 12, 12, 12, 10, 10]);
        t.header(&[
            "size", "pools", "ranks", "flat", "hier", "intra", "inter", "speedup", "verdict",
        ]);
        for &mb in sizes_mb {
            let bytes = mb << 20;
            for pools in [2usize, 4] {
                let set = PoolSet::uniform(pools, per_pool).unwrap();
                let world = set.world_size();
                // Per-rank payload, world-divisible (the intra
                // ReduceScatter leg needs n % per_pool == 0).
                let n = (bytes / 4 / world).max(1) * world;
                let pool_spec = fabric::sim::pool_spec_for(&set, 6, 1, n, Dtype::F32);
                let mut flat_spec = ClusterSpec::new(world, 6, 64 << 20);
                let worst = world * n * 4 + flat_spec.db_region_size + (1 << 20);
                if flat_spec.device_capacity < worst {
                    flat_spec.device_capacity = worst.next_power_of_two();
                }
                let choice = fabric::tune_fabric(
                    &cache, &set, &flat_spec, &pool_spec, p, 0, n, Dtype::F32, ib,
                )
                .unwrap();
                let flat_s = choice.flat.predicted_secs;
                let hier_s = choice.hier.predicted_secs;
                let verdict = if choice.hierarchical { "two-level" } else { "flat" };
                t.row(&[
                    fmt_bytes(bytes),
                    format!("{pools}"),
                    format!("{world}"),
                    fmt_time(flat_s),
                    fmt_time(hier_s),
                    fmt_time(choice.hier_time.intra_secs),
                    fmt_time(choice.hier_time.inter_secs),
                    format!("{:.2}x", flat_s / hier_s),
                    verdict.to_string(),
                ]);
                if p == Primitive::AllReduce {
                    assert!(
                        choice.hierarchical && hier_s < flat_s,
                        "{p} at {pools} pools x {} must pick the two-level path \
                         (flat {flat_s:.4}s vs hier {hier_s:.4}s)",
                        fmt_bytes(bytes)
                    );
                }
                rows.push(format!(
                    "{{\"primitive\": \"{p}\", \"pools\": {pools}, \"ranks\": {world}, \
                     \"bytes\": {bytes}, \"flat_s\": {flat_s:.6}, \"hier_s\": {hier_s:.6}, \
                     \"hier_intra_s\": {:.6}, \"hier_inter_s\": {:.6}, \
                     \"speedup\": {:.3}, \"hierarchical\": {}}}",
                    choice.hier_time.intra_secs,
                    choice.hier_time.inter_secs,
                    flat_s / hier_s,
                    choice.hierarchical,
                ));
            }
        }
    }
    println!(
        "(two-level: RS-intra -> leader AllReduce over IB -> AG-intra; pools own their six\n \
         devices, the flat world crams every rank through one chassis's six)"
    );
    if emit_json {
        let meta = [
            ("per_pool", per_pool.to_string()),
            ("tuner_cache_lines", cache.len().to_string()),
        ];
        match write_bench_json("BENCH_multipool.json", "multipool", &meta, &rows) {
            Ok(()) => println!("\nwrote BENCH_multipool.json ({} rows)", rows.len()),
            Err(e) => eprintln!("\nfailed to write BENCH_multipool.json: {e}"),
        }
    }
}
