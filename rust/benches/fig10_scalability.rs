//! **Figure 10** — scalability: 3 → 6 → 12 nodes over the same six-device
//! pool (the paper's own emulator methodology, §5.3), for the four
//! representative primitives, 128 MB–4 GB.
//!
//! Paper shapes to reproduce:
//! - AllReduce: 2.1–3.0× at 6 nodes, 8.7–12.2× at 12 (reads grow with
//!   ranks and all twelve nodes contend on six devices); NCCL/IB ring
//!   scales better.
//! - Broadcast: 1.26–1.40× at 6 nodes, ~2.5× at 12; ~1.54× faster than IB
//!   on average across all cases.
//! - AllToAll: total traffic is constant in nranks, so growth comes from
//!   contention only: 1.11–1.43× at 6, 1.44–1.83× at 12.
//! - AllGather (4th representative): traffic grows like AllReduce without
//!   the reduction.
//!
//! Run: `cargo bench --bench fig10_scalability`
//! Env: `FIG10_MAX_MB` (default 4096).

use cxl_ccl::baseline::{collective_time, IbParams};
use cxl_ccl::bench_util::{banner, Table};
use cxl_ccl::collectives::builder::plan_collective;
use cxl_ccl::collectives::{run_with_scratch, CclVariant, Primitive};
use cxl_ccl::pool::PoolLayout;
use cxl_ccl::sim::SimFabric;
use cxl_ccl::topology::ClusterSpec;
use cxl_ccl::util::size::{fmt_bytes, fmt_time};

fn sim_time(p: Primitive, nranks: usize, msg_bytes: usize) -> f64 {
    let n = (msg_bytes / 4 / nranks).max(1) * nranks;
    // Virtual capacity sized for the worst per-device footprint.
    let dev_cap = ((nranks * msg_bytes) / 2 + (64 << 20)).next_power_of_two();
    let spec = ClusterSpec::new(nranks, 6, dev_cap);
    let layout = PoolLayout::from_spec(&spec).unwrap();
    let fab = SimFabric::new(layout);
    let plan = plan_collective(p, &spec, &layout, &CclVariant::All.config(8), n).unwrap();
    run_with_scratch(&fab, &plan).unwrap().seconds()
}

fn main() {
    let max_mb: usize = std::env::var("FIG10_MAX_MB")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4096);
    let sizes_mb: Vec<usize> = [128, 512, 1024, 4096]
        .into_iter()
        .filter(|m| *m <= max_mb)
        .collect();
    let ib = IbParams::default();
    let prims = [
        Primitive::AllReduce,
        Primitive::Broadcast,
        Primitive::AllToAll,
        Primitive::AllGather,
    ];

    for p in prims {
        banner(&format!("Fig 10 panel: {p} (6 CXL devices throughout)"));
        let t = Table::new(&[10, 12, 12, 12, 12, 12, 12]);
        t.header(&[
            "size", "cxl@3", "cxl@6", "cxl@12", "x6/x3", "x12/x3", "IB@12",
        ]);
        for &mb in &sizes_mb {
            let bytes = mb << 20;
            let t3 = sim_time(p, 3, bytes);
            let t6 = sim_time(p, 6, bytes);
            let t12 = sim_time(p, 12, bytes);
            let ib12 = collective_time(p, ((bytes / 4 / 12) * 12) * 4, 12, &ib);
            t.row(&[
                fmt_bytes(bytes),
                fmt_time(t3),
                fmt_time(t6),
                fmt_time(t12),
                format!("{:.2}x", t6 / t3),
                format!("{:.2}x", t12 / t3),
                fmt_time(ib12),
            ]);
        }
        match p {
            Primitive::AllReduce => println!(
                "(paper: 2.1-3.0x at 6 nodes, 8.7-12.2x at 12; IB ring reuses partial\n \
                 reductions and scales better — compare cxl@12 vs IB@12)"
            ),
            Primitive::Broadcast => {
                println!("(paper: 1.26-1.40x at 6 nodes, ~2.5x at 12; ~1.54x vs IB on average)")
            }
            Primitive::AllToAll => {
                println!("(paper: 1.11-1.43x at 6 nodes, 1.44-1.83x at 12 — contention only)")
            }
            _ => {}
        }
    }
}
