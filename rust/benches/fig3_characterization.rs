//! **Figure 3** — performance characterization of the CXL shared memory
//! pool on the calibrated virtual-time fabric:
//!
//! - 3a: single-node exclusive-access bandwidth vs transfer size
//!   (reaches ~20 GB/s at 1 MiB; device ×8 link + single DMA engine,
//!   Observation 1),
//! - 3b: concurrent GPU *reads* from the pool,
//! - 3c: concurrent GPU *writes* to the pool
//!   (same-device streams fair-share one card — Observation 2 — while
//!   distinct-device streams scale).
//!
//! Also reproduces the multi-device single-GPU experiment from §3 (the
//! aggregate never exceeds the single-device peak).
//!
//! Run: `cargo bench --bench fig3_characterization`

use cxl_ccl::bench_util::{banner, pow2_sizes, Table};
use cxl_ccl::collectives::ops::{CollectivePlan, Op, RankPlan, ValidPlan};
use cxl_ccl::collectives::{CclVariant, CollectiveBackend, Primitive};
use cxl_ccl::pool::PoolLayout;
use cxl_ccl::sim::SimFabric;
use cxl_ccl::tensor::Dtype;
use cxl_ccl::util::size::fmt_bytes;

// Virtual device capacity. Must hold every concurrent stream of the largest
// sweep size on ONE device (3 servers × 1 GiB + the doorbell region), or the
// "same-device" plans silently spill onto neighbouring devices and the
// Observation-2 contention columns flatten out at large sizes. Simulation
// moves no real bytes, so 4 GiB per device costs nothing.
const DEV_CAP: usize = 4 << 30;

/// `streams` node-streams, each transferring `bytes`; `spread=false` pins
/// all streams to device 0 (contention), `spread=true` gives each its own
/// device. `fan=k`: a single node splits its transfer over k devices.
fn plan(streams: usize, bytes: usize, spread: bool, write: bool, fan: usize) -> CollectivePlan {
    // Fanning splits `bytes` over `fan` devices; the division remainder is
    // spread over the first `bytes % fan` segments so the modeled traffic
    // sums to exactly `bytes` (a bare `bytes / fan` would silently drop up
    // to fan-1 bytes per stream).
    let seg_base = bytes / fan;
    let seg_rem = bytes % fan;
    let mut ranks = Vec::new();
    for r in 0..streams {
        let mut rp = RankPlan::new(r);
        for f in 0..fan {
            let dev = if spread { (r * fan + f) % 6 } else { f % 6 };
            let len = seg_base + usize::from(f < seg_rem);
            let off = dev * DEV_CAP + (1 << 20) + r * (seg_base + 1);
            let op = if write {
                Op::Write { pool_off: off, src_off: 0, len }
            } else {
                Op::Read { pool_off: off, dst_off: 0, len }
            };
            if write {
                rp.write_ops.push(op);
            } else {
                rp.read_ops.push(op);
            }
        }
        ranks.push(rp);
    }
    CollectivePlan {
        primitive: Primitive::Broadcast,
        variant: CclVariant::All,
        nranks: streams,
        n_elems: bytes / 4,
        dtype: Dtype::F32,
        send_elems: bytes / 4,
        recv_elems: bytes / 4,
        ranks,
    }
}

fn main() {
    let layout = PoolLayout::new(6, DEV_CAP, 1 << 20).unwrap();
    let fab = SimFabric::new(layout);
    // Hand-built plans run through the same backend trait as everything
    // else; the fabric is a `CollectiveBackend` like the real executor.
    // `ValidPlan::new` is the launch gate for plans built outside the
    // planner (the planner's own output is already sealed).
    let sim = |p: CollectivePlan| {
        let p = ValidPlan::new(p, layout.pool_size()).expect("synthetic plan is valid");
        fab.run(&p, &[], &mut []).unwrap().seconds()
    };
    let gbps = |bytes: usize, t: f64| bytes as f64 / t / 1e9;

    banner("Figure 3a: single-node exclusive bandwidth vs transfer size");
    let t = Table::new(&[12, 12, 12]);
    t.header(&["size", "read GB/s", "write GB/s"]);
    for bytes in pow2_sizes(16 << 10, 1 << 30) {
        let rd = sim(plan(1, bytes, false, false, 1));
        let wr = sim(plan(1, bytes, false, true, 1));
        t.row(&[
            fmt_bytes(bytes),
            format!("{:.2}", gbps(bytes, rd)),
            format!("{:.2}", gbps(bytes, wr)),
        ]);
    }
    println!("(paper: ~20 GB/s at 1 MiB; limited by the Gen5 x8 device link)");

    banner("§3 multi-device, single GPU: one node fanning over k devices");
    let t = Table::new(&[10, 14]);
    t.header(&["devices", "aggregate GB/s"]);
    for fan in [1usize, 2, 4, 6] {
        let vt = sim(plan(1, 256 << 20, true, false, fan));
        t.row(&[fan.to_string(), format!("{:.2}", gbps(256 << 20, vt))]);
    }
    println!("(paper: aggregate never exceeds the single-device peak — one DMA engine/direction)");

    for (fig, write) in [("3b: concurrent reads", false), ("3c: concurrent writes", true)] {
        banner(&format!("Figure {fig} from multiple servers"));
        let t = Table::new(&[12, 9, 18, 20]);
        t.header(&["size", "servers", "same-dev GB/s/srv", "distinct-dev GB/s/srv"]);
        for bytes in pow2_sizes(1 << 20, 1 << 30) {
            for servers in [2usize, 3] {
                let same = sim(plan(servers, bytes, false, write, 1));
                let diff = sim(plan(servers, bytes, true, write, 1));
                t.row(&[
                    fmt_bytes(bytes),
                    servers.to_string(),
                    format!("{:.2}", gbps(bytes, same)),
                    format!("{:.2}", gbps(bytes, diff)),
                ]);
            }
        }
        println!("(paper Observation 2: same-device concurrent requests split bandwidth evenly)");
    }
}
