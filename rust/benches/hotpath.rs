//! Hot-path microbenchmarks (the §Perf iteration targets): doorbell
//! ring/wait cost, pool memcpy bandwidth, reduce-engine throughput
//! (scalar vs AOT-Pallas-via-PJRT), plan building, and real-executor
//! end-to-end latency per variant.
//!
//! Run: `cargo bench --bench hotpath`
//! Env: `BENCH_JSON=1` additionally writes machine-readable
//! `BENCH_hotpath.json` (one row per measured cell) for the CI perf
//! trajectory.

use cxl_ccl::bench_util::{banner, measure, write_bench_json, Table};
use cxl_ccl::collectives::builder::plan_collective;
use cxl_ccl::collectives::{CclVariant, CollectiveBackend, PlanCache, Primitive};
use cxl_ccl::doorbell::{DoorbellSet, WaitPolicy};
use cxl_ccl::exec::{Communicator, ReduceEngine, ScalarReduceEngine};
use cxl_ccl::pool::{PoolLayout, ShmPool};
use cxl_ccl::tensor::{views_f32, views_f32_mut, Dtype};
use cxl_ccl::topology::ClusterSpec;
use cxl_ccl::util::size::{fmt_bytes, fmt_time};
use cxl_ccl::util::SplitMix64;

/// One measured cell for the JSON artifact: which section, which cell
/// within it, and the p50 plus a section-appropriate rate.
fn json_row(section: &str, cell: &str, p50_s: f64, gbps: f64) -> String {
    format!(
        "{{\"section\": \"{section}\", \"cell\": \"{cell}\", \"p50_ns\": {:.1}, \
         \"gbps\": {gbps:.3}}}",
        p50_s * 1e9
    )
}

fn main() {
    let emit_json = std::env::var("BENCH_JSON").map(|v| v == "1").unwrap_or(false);
    let mut rows: Vec<String> = Vec::new();

    banner("doorbell: ring + already-ready wait");
    let layout = PoolLayout::new(2, 4 << 20, 1 << 20).unwrap();
    let pool = ShmPool::anon(layout.pool_size()).unwrap();
    let dbs = DoorbellSet::new(&pool, layout);
    dbs.reset_all().unwrap();
    let policy = WaitPolicy::default();
    let s = measure(100, 10_000, || {
        dbs.ring(7).unwrap();
        dbs.wait(7, &policy).unwrap();
    });
    println!("ring+wait p50 {} mean {}", fmt_time(s.p50), fmt_time(s.mean));
    rows.push(json_row("doorbell", "ring_wait", s.p50, 0.0));

    banner("pool memcpy bandwidth (this host's hardware floor)");
    let t = Table::new(&[12, 14, 14]);
    t.header(&["size", "write GB/s", "read GB/s"]);
    let big = ShmPool::anon(256 << 20).unwrap();
    for bytes in [64 << 10, 1 << 20, 16 << 20, 128 << 20] {
        let src = vec![7u8; bytes];
        let mut dst = vec![0u8; bytes];
        let w = measure(2, 8, || big.write_bytes(0, &src).unwrap());
        let r = measure(2, 8, || big.read_bytes(0, &mut dst).unwrap());
        t.row(&[
            fmt_bytes(bytes),
            format!("{:.2}", bytes as f64 / w.p50 / 1e9),
            format!("{:.2}", bytes as f64 / r.p50 / 1e9),
        ]);
        rows.push(json_row(
            "memcpy",
            &format!("write_{}", fmt_bytes(bytes)),
            w.p50,
            bytes as f64 / w.p50 / 1e9,
        ));
        rows.push(json_row(
            "memcpy",
            &format!("read_{}", fmt_bytes(bytes)),
            r.p50,
            bytes as f64 / r.p50 / 1e9,
        ));
    }

    banner("reduce engine: scalar vs AOT Pallas kernel via PJRT");
    let n = 262_144usize;
    let mut rng = SplitMix64::new(3);
    let mut data = vec![0.0f32; n];
    rng.fill_f32(&mut data);
    let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
    big.write_bytes(0, &bytes).unwrap();
    let mut acc = vec![0.0f32; n];
    let s = measure(3, 20, || {
        ScalarReduceEngine.reduce_into(&big, 0, &mut acc).unwrap();
    });
    println!(
        "scalar:      p50 {} -> {:.2} GB/s",
        fmt_time(s.p50),
        (n * 4) as f64 / s.p50 / 1e9
    );
    rows.push(json_row("reduce", "scalar", s.p50, (n * 4) as f64 / s.p50 / 1e9));
    match cxl_ccl::runtime::PjrtRuntime::cpu() {
        Ok(rt) => {
            let k = rt.reduce_kernel(n).unwrap();
            let engine = cxl_ccl::exec::PjrtReduceEngine::new(k);
            let s = measure(3, 20, || {
                engine.reduce_into(&big, 0, &mut acc).unwrap();
            });
            println!(
                "pjrt-pallas: p50 {} -> {:.2} GB/s (tile {} elems)",
                fmt_time(s.p50),
                (n * 4) as f64 / s.p50 / 1e9,
                engine.tile_elems()
            );
            rows.push(json_row("reduce", "pjrt_pallas", s.p50, (n * 4) as f64 / s.p50 / 1e9));
        }
        Err(e) => println!("pjrt-pallas: skipped ({e})"),
    }

    banner("plan building overhead: fresh vs PlanCache steady-state");
    let spec = ClusterSpec::paper(64 << 20);
    let playout = PoolLayout::from_spec(&spec).unwrap();
    for p in [Primitive::AllGather, Primitive::AllToAll] {
        let s = measure(10, 200, || {
            let _ = plan_collective(p, &spec, &playout, &CclVariant::All.config(8), 3 << 20)
                .unwrap();
        });
        let cache = PlanCache::new();
        let c = measure(10, 200, || {
            let _ = cache
                .get_or_plan(&spec, &playout, p, &CclVariant::All.config(8), 3 << 20, Dtype::F32)
                .unwrap();
        });
        println!(
            "plan {p}: fresh p50 {} | cached p50 {} ({:.0}x)",
            fmt_time(s.p50),
            fmt_time(c.p50),
            s.p50 / c.p50.max(1e-12)
        );
        rows.push(json_row("plan", &format!("{p}_fresh"), s.p50, 0.0));
        rows.push(json_row("plan", &format!("{p}_cached"), c.p50, 0.0));
    }

    banner("real executor end-to-end (4MiB AllGather, thread-per-rank)");
    let comm = Communicator::shm(&spec).unwrap();
    let n = 1 << 20; // 4 MiB per rank
    let sends: Vec<Vec<f32>> = (0..3).map(|_| vec![1.0f32; n]).collect();
    let t = Table::new(&[20, 12, 14]);
    t.header(&["variant", "p50", "pool GB/s"]);
    for v in CclVariant::ALL {
        let ccl = v.config(8);
        // Cached plan + the unified backend trait: the steady-state loop
        // every migrated caller now runs.
        let plan = comm.plan(Primitive::AllGather, &ccl, n, Dtype::F32).unwrap();
        let mut recvs = vec![vec![0.0f32; n * 3]; 3];
        let s = measure(2, 10, || {
            let send_views = views_f32(&sends);
            let mut recv_views = views_f32_mut(&mut recvs);
            comm.run(&plan, &send_views, &mut recv_views).unwrap();
        });
        t.row(&[
            v.name().into(),
            fmt_time(s.p50),
            format!("{:.2}", plan.total_pool_bytes() as f64 / s.p50 / 1e9),
        ]);
        rows.push(json_row(
            "executor",
            v.name(),
            s.p50,
            plan.total_pool_bytes() as f64 / s.p50 / 1e9,
        ));
    }
    let stats = comm.plan_cache().stats();
    println!("plan cache after the sweep: {} misses, {} hits", stats.misses, stats.hits);

    if emit_json {
        match write_bench_json("BENCH_hotpath.json", "hotpath", &[], &rows) {
            Ok(()) => println!("\nwrote BENCH_hotpath.json ({} rows)", rows.len()),
            Err(e) => eprintln!("\nfailed to write BENCH_hotpath.json: {e}"),
        }
    }
}
