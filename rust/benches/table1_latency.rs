//! **Table 1** — MLC access latency: local DRAM 214 ns vs CXL pool 658 ns
//! (3.1×). The calibrated model values are reported alongside an MLC-style
//! dependent-load pointer chase measured on this host's mapped pool (the
//! methodology demonstration; no CXL switch exists here).
//!
//! Run: `cargo bench --bench table1_latency`

use cxl_ccl::bench_util::{banner, Table};
use cxl_ccl::pool::ShmPool;
use cxl_ccl::sim::latency::{pointer_chase, LatencyModel};
use cxl_ccl::util::Stats;

fn main() {
    banner("Table 1: access latency (paper: DRAM 214ns, CXL pool 658ns, 3.1x)");
    let m = LatencyModel::default();
    let t = Table::new(&[34, 12, 12]);
    t.header(&["path", "latency", "ratio"]);
    t.row(&[
        "local DRAM (paper, Intel MLC)".into(),
        format!("{:.0}ns", m.dram * 1e9),
        "1.00x".into(),
    ]);
    t.row(&[
        "CXL pool via switch (paper, MLC)".into(),
        format!("{:.0}ns", m.cxl_pool * 1e9),
        format!("{:.2}x", m.ratio()),
    ]);

    // Host measurement: MLC-style chase over small (cache-resident) and
    // large (DRAM-resident) working sets on the mapped pool.
    let pool = ShmPool::anon(256 << 20).unwrap();
    for (label, ws) in [
        ("this host, 64KiB working set", 64 << 10),
        ("this host, 128MiB working set", 128 << 20),
    ] {
        let samples: Vec<f64> = (0..5)
            .map(|_| pointer_chase(&pool, 0, ws, 100_000))
            .collect();
        let s = Stats::from(&samples);
        t.row(&[
            label.into(),
            format!("{:.1}ns", s.p50 * 1e9),
            format!("{:.2}x", s.p50 / samples.iter().cloned().fold(f64::MAX, f64::min).max(1e-12)),
        ]);
    }
    println!("\nnote: the host rows demonstrate the MLC methodology; the paper rows are");
    println!("the calibrated constants every virtual-time result in this repo uses.");
}
