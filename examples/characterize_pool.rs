//! Pool characterization in the style of paper §3 (Table 1 + Figure 3):
//! measured host-side numbers for the mapped pool plus the calibrated
//! virtual-time curves for the CXL fabric.
//!
//! Run: `cargo run --release --example characterize_pool`

use cxl_ccl::bench_util::{banner, pow2_sizes, Table};
use cxl_ccl::collectives::ops::{CollectivePlan, Op, RankPlan, ValidPlan};
use cxl_ccl::collectives::{CclVariant, CollectiveBackend, Primitive};
use cxl_ccl::pool::{PoolLayout, ShmPool};
use cxl_ccl::sim::constants as k;
use cxl_ccl::sim::latency::{pointer_chase, LatencyModel};
use cxl_ccl::sim::{SimFabric, SimParams};
use cxl_ccl::tensor::Dtype;
use cxl_ccl::util::size::fmt_bytes;
use std::time::Instant;

/// Virtual device capacity: must hold all 3 concurrent 1 GiB streams on one
/// device so the "same-device" rows actually contend (the pool is simulated,
/// so the size is free). Keep in sync with the `PoolLayout` below.
const DEV_CAP: usize = 4 << 30;

/// Hand-built plan: `streams` ranks each moving `bytes` to/from device 0 or
/// distinct devices — the §3 concurrency microbenchmarks.
fn transfer_plan(streams: usize, bytes: usize, same_device: bool, write: bool) -> ValidPlan {
    let mut ranks = Vec::new();
    for r in 0..streams {
        let mut rp = RankPlan::new(r);
        let base = if same_device { 0 } else { r * DEV_CAP };
        let off = base + (1 << 20) + if same_device { r * bytes } else { 0 };
        if write {
            rp.write_ops.push(Op::Write { pool_off: off, src_off: 0, len: bytes });
        } else {
            rp.read_ops.push(Op::Read { pool_off: off, dst_off: 0, len: bytes });
        }
        ranks.push(rp);
    }
    let plan = CollectivePlan {
        primitive: Primitive::Broadcast,
        variant: CclVariant::All,
        nranks: streams,
        n_elems: bytes / 4,
        dtype: Dtype::F32,
        send_elems: bytes / 4,
        recv_elems: bytes / 4,
        ranks,
    };
    // Hand-built plans enter the launch surface through the ValidPlan gate.
    ValidPlan::new(plan, 6 * DEV_CAP).expect("synthetic transfer plan is valid")
}

fn main() -> anyhow::Result<()> {
    banner("Table 1: access latency");
    let model = LatencyModel::default();
    let pool = ShmPool::anon(64 << 20)?;
    let host = pointer_chase(&pool, 0, 32 << 20, 200_000);
    let t = Table::new(&[28, 14]);
    t.header(&["path", "latency"]);
    t.row(&["local DRAM (paper, MLC)".into(), format!("{:.0}ns", model.dram * 1e9)]);
    t.row(&["CXL pool (paper, MLC)".into(), format!("{:.0}ns", model.cxl_pool * 1e9)]);
    t.row(&["ratio (paper: 3.1x)".into(), format!("{:.2}x", model.ratio())]);
    t.row(&["this host, mapped pool chase".into(), format!("{:.1}ns", host * 1e9)]);

    banner("Figure 3a: single-node bandwidth vs transfer size (virtual time)");
    let layout = PoolLayout::new(6, DEV_CAP, 1 << 20)?;
    let fab = SimFabric::new(layout).with_params(SimParams::default());
    let t = Table::new(&[12, 14, 14]);
    t.header(&["size", "read GB/s", "write GB/s"]);
    for bytes in pow2_sizes(4 << 10, 1 << 30) {
        let mut row = vec![fmt_bytes(bytes)];
        for write in [false, true] {
            let out = fab.run(&transfer_plan(1, bytes, true, write), &[], &mut [])?;
            row.push(format!("{:.2}", bytes as f64 / out.seconds() / 1e9));
        }
        t.row(&row);
    }
    println!(
        "(plateau = {:.0} GB/s: the Gen5 x8 device limit, Observation 1)",
        k::CXL_DEVICE_BW / 1e9
    );

    banner("Figure 3b/3c: concurrent streams, same vs distinct devices (virtual time)");
    let t = Table::new(&[12, 10, 16, 18]);
    t.header(&["size", "streams", "same-dev GB/s", "distinct-dev GB/s"]);
    for bytes in pow2_sizes(1 << 20, 1 << 30) {
        for streams in [2usize, 3] {
            let same = fab.run(&transfer_plan(streams, bytes, true, false), &[], &mut [])?;
            let diff = fab.run(&transfer_plan(streams, bytes, false, false), &[], &mut [])?;
            t.row(&[
                fmt_bytes(bytes),
                streams.to_string(),
                format!("{:.2} per-stream", bytes as f64 / same.seconds() / 1e9),
                format!("{:.2} per-stream", bytes as f64 / diff.seconds() / 1e9),
            ]);
        }
    }
    println!("(same-device streams fair-share one card, Observation 2)");

    banner("measured host memcpy into the mapped pool (hardware floor on this box)");
    let buf = vec![0u8; 64 << 20];
    let t0 = Instant::now();
    pool.write_bytes(0, &buf)?;
    let dt = t0.elapsed().as_secs_f64();
    println!("64MiB memcpy: {:.2} GB/s", 64e6 * 1.048576 / dt / 1e9);
    Ok(())
}
