//! Quickstart: stand up the paper's topology (3 nodes, 6 CXL devices),
//! run a few collectives for real over the shared pool, verify the
//! numerics, and show the virtual-time CXL-vs-InfiniBand comparison.
//!
//! Run: `cargo run --release --example quickstart`

use cxl_ccl::baseline::{collective_time, IbParams};
use cxl_ccl::collectives::builder::plan_collective;
use cxl_ccl::collectives::{oracle, CclConfig, CclVariant, Primitive};
use cxl_ccl::exec::Communicator;
use cxl_ccl::pool::PoolLayout;
use cxl_ccl::sim::SimFabric;
use cxl_ccl::topology::ClusterSpec;
use cxl_ccl::util::size::{fmt_bytes, fmt_time};
use cxl_ccl::util::SplitMix64;

fn main() -> anyhow::Result<()> {
    cxl_ccl::util::logger::init();

    // The paper's testbed shape, with 32 MiB devices (scaled from 128 GB).
    let spec = ClusterSpec::paper(32 << 20);
    let comm = Communicator::shm(&spec)?;
    println!(
        "pool: {} devices x {} = {} (doorbell region {})",
        spec.ndevices,
        fmt_bytes(spec.device_capacity),
        fmt_bytes(spec.pool_size()),
        fmt_bytes(spec.db_region_size),
    );

    // --- 1. AllReduce, verified against the oracle ----------------------
    let n = 3 * 65536; // 768 KiB per rank
    let mut rng = SplitMix64::new(42);
    let sends: Vec<Vec<f32>> = (0..spec.nranks)
        .map(|_| {
            let mut v = vec![0.0f32; n];
            rng.fill_f32(&mut v);
            v
        })
        .collect();
    let cfg = CclConfig::default_all();
    let mut recvs = vec![vec![0.0f32; n]; spec.nranks];
    let wall = comm.execute(Primitive::AllReduce, &cfg, n, &sends, &mut recvs)?;
    let want = oracle::expected(Primitive::AllReduce, &sends, n, 0);
    let max_err = recvs
        .iter()
        .zip(&want)
        .flat_map(|(got, exp)| got.iter().zip(exp).map(|(g, e)| (g - e).abs() as f64))
        .fold(0.0, f64::max);
    println!(
        "allreduce({} x {} ranks): wall {} | max |err| = {max_err:.2e}  ✓",
        fmt_bytes(n * 4),
        spec.nranks,
        fmt_time(wall.as_secs_f64()),
    );

    // --- 2. AllGather through the convenience API ------------------------
    let gathered = comm.all_gather_f32(&sends, &cfg)?;
    assert!(gathered.iter().all(|g| g.len() == n * spec.nranks));
    println!("allgather: every rank holds {} ✓", fmt_bytes(n * 4 * spec.nranks));

    // --- 3. The three variants in virtual time vs InfiniBand -------------
    // (virtual pool sized for the message; simulation moves no real bytes)
    let msg = 64 << 20; // 64 MiB message on the calibrated fabric
    let sim_spec = ClusterSpec::new(spec.nranks, spec.ndevices, 1 << 30);
    let layout = PoolLayout::from_spec(&sim_spec)?;
    let fab = SimFabric::new(layout);
    let n_sim = msg / 4;
    println!("\nvirtual-time AllGather, {} per rank:", fmt_bytes(msg));
    for v in CclVariant::ALL {
        let plan = plan_collective(Primitive::AllGather, &sim_spec, &layout, &v.config(8), n_sim)?;
        let rep = fab.simulate(&plan)?;
        println!(
            "  {:<18} {}  (pool throughput {:.1} GB/s)",
            v.name(),
            fmt_time(rep.total_time),
            rep.pool_throughput() / 1e9,
        );
    }
    let ib = collective_time(Primitive::AllGather, msg, spec.nranks, &IbParams::default());
    println!("  {:<18} {}", "infiniband-200g", fmt_time(ib));
    Ok(())
}
