//! Quickstart: stand up the paper's topology (3 nodes, 6 CXL devices),
//! run collectives through the current API — typed tensor views, per-rank
//! nonblocking handles, process groups with typed pipelined launches, and
//! the one `CollectiveBackend` trait that drives both the real pool
//! executor and the virtual-time fabric — and verify the numerics.
//!
//! Run: `cargo run --release --example quickstart`

use cxl_ccl::baseline::{collective_time, IbParams};
use cxl_ccl::collectives::oracle;
use cxl_ccl::prelude::*;
use cxl_ccl::tensor::{views_f32, views_f32_mut};
use cxl_ccl::util::size::{fmt_bytes, fmt_time};
use cxl_ccl::util::SplitMix64;

fn main() -> anyhow::Result<()> {
    cxl_ccl::util::logger::init();

    // The paper's testbed shape, with 32 MiB devices (scaled from 128 GB).
    let spec = ClusterSpec::paper(32 << 20);
    let comm = Communicator::shm(&spec)?;
    println!(
        "pool: {} devices x {} = {} (doorbell region {})",
        spec.ndevices,
        fmt_bytes(spec.device_capacity),
        fmt_bytes(spec.pool_size()),
        fmt_bytes(spec.db_region_size),
    );

    // --- 1. AllReduce over typed views, verified against the oracle -----
    let n = 3 * 65536; // 768 KiB per rank
    let mut rng = SplitMix64::new(42);
    let sends: Vec<Vec<f32>> = (0..spec.nranks)
        .map(|_| {
            let mut v = vec![0.0f32; n];
            rng.fill_f32(&mut v);
            v
        })
        .collect();
    let cfg = CclVariant::All.config(8);
    let mut recvs = vec![vec![0.0f32; n]; spec.nranks];
    let wall = {
        let send_views = views_f32(&sends);
        let mut recv_views = views_f32_mut(&mut recvs);
        comm.collective(Primitive::AllReduce, &cfg, n, &send_views, &mut recv_views)?
    };
    let want = oracle::expected(Primitive::AllReduce, &sends, n, 0);
    let max_err = recvs
        .iter()
        .zip(&want)
        .flat_map(|(got, exp)| got.iter().zip(exp).map(|(g, e)| (g - e).abs() as f64))
        .fold(0.0, f64::max);
    println!(
        "allreduce({} x {} ranks): wall {} | max |err| = {max_err:.2e}  ✓",
        fmt_bytes(n * 4),
        spec.nranks,
        fmt_time(wall.as_secs_f64()),
    );

    // --- 2. Nonblocking per-rank handles (ncclGroupStart/End-style) ------
    let pending: Vec<PendingOp<'_>> = (0..spec.nranks)
        .map(|r| {
            comm.rank(r)?.begin(
                Primitive::AllGather,
                &cfg,
                n,
                Tensor::from_f32(&sends[r]),
                Tensor::zeros(Dtype::F32, n * spec.nranks),
            )
        })
        .collect::<anyhow::Result<_>>()?;
    for p in pending {
        let (gathered, _) = p.wait()?;
        assert_eq!(gathered.len(), n * spec.nranks);
    }
    println!(
        "allgather via rank handles: every rank holds {} ✓",
        fmt_bytes(n * 4 * spec.nranks)
    );

    // --- 3. One plan, two backends -----------------------------------------
    // The identical cached plan runs for real over the pool and in virtual
    // time on the calibrated fabric, through the same trait.
    let plan = comm.plan(Primitive::AllGather, &cfg, n, Dtype::F32)?;
    let fabric = SimFabric::new(*comm.layout());
    println!("\none plan, two backends (AllGather, {} per rank):", fmt_bytes(n * 4));
    for backend in [&comm as &dyn CollectiveBackend, &fabric] {
        let out = run_with_scratch(backend, &plan)?;
        println!(
            "  {:<10} {}  ({})",
            backend.name(),
            fmt_time(out.seconds()),
            if out.is_virtual() { "virtual time" } else { "wall clock" },
        );
    }
    let stats = comm.plan_cache().stats();
    println!(
        "plan cache: {} misses, {} hits (steady-state calls replan nothing)",
        stats.misses, stats.hits
    );

    // --- 4. The three variants in virtual time vs InfiniBand -------------
    // (virtual pool sized for the message; simulation moves no real bytes)
    let msg = 64 << 20; // 64 MiB message on the calibrated fabric
    let sim_spec = ClusterSpec::new(spec.nranks, spec.ndevices, 1 << 30);
    let layout = cxl_ccl::pool::PoolLayout::from_spec(&sim_spec)?;
    let fab = SimFabric::new(layout);
    let n_sim = msg / 4;
    println!("\nvirtual-time AllGather, {} per rank:", fmt_bytes(msg));
    for v in CclVariant::ALL {
        let plan = plan_collective(Primitive::AllGather, &sim_spec, &layout, &v.config(8), n_sim)?;
        let out = run_with_scratch(&fab, &plan)?;
        println!(
            "  {:<18} {}  (pool throughput {:.1} GB/s)",
            v.name(),
            fmt_time(out.seconds()),
            out.sim_report().map(|r| r.pool_throughput() / 1e9).unwrap_or(0.0),
        );
    }
    let ib = collective_time(Primitive::AllGather, msg, spec.nranks, &IbParams::default());
    println!("  {:<18} {}", "infiniband-200g", fmt_time(ib));

    // --- 5. v3 process groups: split one world into concurrent subgroups --
    // (Pool bootstrap — `Bootstrap::pool(path, spec)` — does the same across
    // OS processes; see `cxl-ccl run --bootstrap pool:<path>`.)
    let pg = CommWorld::init(
        Bootstrap::thread_local(ClusterSpec::new(4, 6, 16 << 20)),
        0,
        4,
    )?;
    let subs = pg.split_all(&[(0, 0), (0, 1), (1, 0), (1, 1)])?;
    println!("\nsplit 4 ranks into {} subgroups sharing one pool:", subs.len());
    for sg in &subs {
        println!(
            "  ranks {:?} | doorbell slots {:?} | devices {:?}",
            sg.global_ranks(),
            sg.doorbell_slot_range(),
            sg.device_range(),
        );
    }
    // Disjoint doorbell + device windows let the subgroups launch at the
    // same time without touching each other's slots or data. Launches use
    // the v4 typed nonblocking surface: issue, hold the futures, wait.
    std::thread::scope(|s| {
        for sg in &subs {
            s.spawn(move || {
                let futures: Vec<CollectiveFuture<'_>> = (0..sg.world_size())
                    .map(|r| {
                        sg.collective_rank(
                            r,
                            Primitive::AllReduce,
                            &cfg,
                            512,
                            Tensor::from_f32(&vec![1.0; 512]),
                            Tensor::zeros(Dtype::F32, 512),
                        )
                        .unwrap()
                    })
                    .collect();
                for f in futures {
                    let (out, _) = f.wait().unwrap();
                    assert!(out.to_f32().unwrap().iter().all(|v| *v == 2.0));
                }
            });
        }
    });
    println!("concurrent subgroup AllReduce over one pool ✓");

    // --- 6. pipelined launches over the epoch ring -------------------------
    // Hold launch N's futures while issuing launch N+1: with the default
    // ring depth 2, publication of N+1 overlaps the drain of N on disjoint
    // doorbell slots and devices (deeper rings via
    // Bootstrap::with_pipeline_depth).
    let world = CommWorld::init(
        Bootstrap::thread_local(ClusterSpec::new(2, 6, 16 << 20)),
        0,
        2,
    )?;
    fn issue<'g>(
        world: &'g ProcessGroup,
        cfg: &CclConfig,
        fill: f32,
    ) -> anyhow::Result<Vec<CollectiveFuture<'g>>> {
        (0..2)
            .map(|r| {
                world.collective_rank(
                    r,
                    Primitive::AllReduce,
                    cfg,
                    1024,
                    Tensor::from_f32(&vec![fill; 1024]),
                    Tensor::zeros(Dtype::F32, 1024),
                )
            })
            .collect()
    }
    let first = issue(&world, &cfg, 1.0)?;
    let second = issue(&world, &cfg, 10.0)?; // in flight while `first` drains
    for (futs, want) in [(first, 2.0f32), (second, 20.0)] {
        for f in futs {
            let (out, _) = f.wait()?;
            assert!(out.to_f32()?.iter().all(|v| *v == want));
        }
    }
    world.flush()?;
    println!(
        "pipelined launches (depth {}) over the epoch ring ✓",
        world.pipeline_depth()
    );
    Ok(())
}
