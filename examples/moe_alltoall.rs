//! MoE expert-parallel routing — the AllToAll workload the paper's intro
//! motivates (token batches routed to distributed expert layers).
//!
//! Each rank hosts one expert. Per layer: tokens are routed to their expert
//! with **AllToAll over the CXL pool**, transformed by the expert (a toy
//! FFN here), and routed back with a second AllToAll. Correctness is
//! checked token-by-token; latency is reported for the real pool executor
//! and in virtual time against InfiniBand.
//!
//! Run: `cargo run --release --example moe_alltoall -- [--tokens 4096] [--dmodel 64]`

use cxl_ccl::baseline::{collective_time, IbParams};
use cxl_ccl::collectives::builder::plan_collective;
use cxl_ccl::collectives::{run_with_scratch, CclVariant, Primitive};
use cxl_ccl::exec::{Communicator, PendingOp};
use cxl_ccl::pool::PoolLayout;
use cxl_ccl::sim::SimFabric;
use cxl_ccl::tensor::{Dtype, Tensor};
use cxl_ccl::topology::ClusterSpec;
use cxl_ccl::util::size::{fmt_bytes, fmt_time};
use cxl_ccl::util::SplitMix64;

fn arg(name: &str, default: usize) -> usize {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// The "expert": a deterministic per-expert transform so routing is
/// verifiable (expert e scales by (e+1) and adds e).
fn expert_transform(expert: usize, x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = *v * (expert as f32 + 1.0) + expert as f32;
    }
}

fn main() -> anyhow::Result<()> {
    cxl_ccl::util::logger::init();
    let nranks = 4usize; // experts == ranks
    let tokens_per_rank = arg("--tokens", 4096);
    let d_model = arg("--dmodel", 64);
    let spec = ClusterSpec::new(nranks, 6, 64 << 20);
    let comm = Communicator::shm(&spec)?;
    let cfg = CclVariant::All.config(8);

    // Capacity-factor routing: each rank sends tokens_per_rank/nranks
    // tokens to every expert (the balanced MoE dispatch the paper's
    // AllToAll pattern assumes). Segment s of rank r's send buffer =
    // tokens destined for expert s.
    let cap = tokens_per_rank / nranks;
    let n_elems = nranks * cap * d_model; // send buffer per rank
    let mut rng = SplitMix64::new(7);
    let sends: Vec<Vec<f32>> = (0..nranks)
        .map(|_| {
            let mut v = vec![0.0f32; n_elems];
            rng.fill_f32(&mut v);
            v
        })
        .collect();

    // Every layer launches through the per-rank nonblocking handles: each
    // expert-rank begins its part of the AllToAll, the group fires once the
    // last rank joins, and the second layer's launch reuses the cached plan.
    let alltoall = |bufs: &[Vec<f32>]| -> anyhow::Result<Vec<Vec<f32>>> {
        let pending: Vec<PendingOp<'_>> = bufs
            .iter()
            .enumerate()
            .map(|(r, b)| {
                comm.rank(r)?.begin(
                    Primitive::AllToAll,
                    &cfg,
                    n_elems,
                    Tensor::from_f32(b),
                    Tensor::zeros(Dtype::F32, n_elems),
                )
            })
            .collect::<anyhow::Result<_>>()?;
        pending
            .into_iter()
            .map(|p| p.wait()?.0.to_f32())
            .collect()
    };

    // ---- dispatch: tokens -> experts ------------------------------------
    let t0 = std::time::Instant::now();
    let mut at_expert = alltoall(&sends)?;
    // ---- expert compute ---------------------------------------------------
    for (e, buf) in at_expert.iter_mut().enumerate() {
        expert_transform(e, buf);
    }
    // ---- combine: experts -> tokens --------------------------------------
    let returned = alltoall(&at_expert)?;
    let wall = t0.elapsed().as_secs_f64();

    // ---- verify: token j sent from rank r to expert e comes back as
    //      expert_transform(e, token) in segment e of rank r ---------------
    let seg = n_elems / nranks;
    let mut checked = 0usize;
    for r in 0..nranks {
        for e in 0..nranks {
            for i in 0..seg {
                let mut want = sends[r][e * seg + i];
                let w = std::slice::from_mut(&mut want);
                expert_transform(e, w);
                let got = returned[r][e * seg + i];
                assert!(
                    (got - want).abs() <= 1e-4 * want.abs().max(1.0),
                    "rank {r} expert {e} token-elem {i}: {got} vs {want}"
                );
                checked += 1;
            }
        }
    }

    println!(
        "MoE dispatch+combine: {} ranks/experts, {} tokens/rank, d_model {}",
        nranks, tokens_per_rank, d_model
    );
    println!(
        "payload {} per rank per alltoall; {checked} token-elements verified ✓",
        fmt_bytes(n_elems * 4)
    );
    println!("real pool executor (2x alltoall + expert compute): {}", fmt_time(wall));
    let stats = comm.plan_cache().stats();
    println!(
        "plan cache: {} misses, {} hits (the combine layer replans nothing)",
        stats.misses, stats.hits
    );

    // ---- virtual-time comparison ----------------------------------------
    let layout = PoolLayout::from_spec(&spec)?;
    let fab = SimFabric::new(layout);
    let plan = plan_collective(Primitive::AllToAll, &spec, &layout, &cfg, n_elems)?;
    let cxl = 2.0 * run_with_scratch(&fab, &plan)?.seconds();
    let ib = 2.0 * collective_time(Primitive::AllToAll, n_elems * 4, nranks, &IbParams::default());
    println!(
        "virtual time per MoE layer: CXL {} vs IB {} ({:.2}x)",
        fmt_time(cxl),
        fmt_time(ib),
        ib / cxl
    );
    Ok(())
}
