//! **End-to-end driver** (paper §5.5): FSDP training of the transformer LM
//! with every collective going through CXL-CCL over the shared pool and all
//! compute running as AOT artifacts via PJRT. Logs the loss curve and the
//! per-step communication cost (real wall time + virtual-time CXL vs
//! InfiniBand), ending with the case-study summary (speedup + interconnect
//! cost ratio).
//!
//! Run: `cargo run --release --example train_fsdp -- [--preset tiny|e2e]
//!      [--steps N] [--variant auto|all|aggregate|naive] [--chunks K]`
//!
//! The run recorded in EXPERIMENTS.md used `--preset e2e --steps 120` (a
//! 10.8M-parameter model; DESIGN.md documents the scale substitution).

use cxl_ccl::config::parse_ccl;
use cxl_ccl::cost;
use cxl_ccl::train::{FsdpTrainer, TrainConfig};
use cxl_ccl::util::size::fmt_time;

fn arg(name: &str, default: &str) -> String {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| default.to_string())
}

fn main() -> anyhow::Result<()> {
    cxl_ccl::util::logger::init();
    let cfg = TrainConfig {
        preset: arg("--preset", "tiny"),
        steps: arg("--steps", "40").parse()?,
        ccl: parse_ccl(Some(&arg("--variant", "auto")), arg("--chunks", "8").parse()?)?,
        seed: arg("--seed", "0").parse()?,
        ndevices: arg("--devices", "6").parse()?,
        comm_buckets: arg("--buckets", "2").parse()?,
        pipeline_depth: arg("--pipeline-depth", "2").parse()?,
    };
    println!(
        "FSDP case study: preset={} steps={} ccl={}",
        cfg.preset,
        cfg.steps,
        cfg.ccl.describe()
    );

    // The trainer needs the PJRT runtime (AOT artifacts + `pjrt` wiring);
    // without it this example skips instead of erroring, like the runtime
    // integration tests.
    let mut trainer = match FsdpTrainer::new(cfg.clone()) {
        Ok(t) => t,
        Err(e) => {
            println!("SKIP: {e:#}");
            println!(
                "(produce artifacts with `python -m compile.aot` and wire the `pjrt` feature)"
            );
            return Ok(());
        }
    };
    println!(
        "model: {} params, {} ranks, {} moved per rank per step",
        trainer.n_params(),
        trainer.nranks(),
        cxl_ccl::util::size::fmt_bytes(trainer.comm_bytes_per_step()),
    );
    println!("\nstep   loss      comm(wall)  compute(wall)  comm(sim CXL)  comm(sim IB)");

    let log_every = (cfg.steps / 20).max(1);
    let reports = trainer.train(|r| {
        if r.step % log_every == 0 || r.step == 1 {
            println!(
                "{:<6} {:<9.4} {:<11} {:<14} {:<14} {}",
                r.step,
                r.loss,
                fmt_time(r.comm_secs),
                fmt_time(r.compute_secs),
                fmt_time(r.sim_cxl_secs),
                fmt_time(r.sim_ib_secs),
            );
        }
    })?;

    // ---- case-study summary ---------------------------------------------
    let first = reports.first().unwrap();
    let last = reports.last().unwrap();
    let sim_cxl: f64 = reports.iter().map(|r| r.sim_cxl_secs).sum();
    let sim_ib: f64 = reports.iter().map(|r| r.sim_ib_secs).sum();
    let compute: f64 = reports.iter().map(|r| r.compute_secs).sum();
    // End-to-end: compute is identical on both fabrics; communication
    // differs. Scale compute to the paper's regime where comm is ~35% of
    // step time on IB (H100-class compute); here CPU compute would swamp
    // it, so report both raw and comm-normalized speedup.
    let comm_speedup = sim_ib / sim_cxl;
    let e2e_paper_mix = (0.65 + 0.35) / (0.65 + 0.35 / comm_speedup);
    println!("\nloss: {:.4} -> {:.4} over {} steps", first.loss, last.loss, reports.len());
    println!(
        "communication (virtual time): CXL {} vs IB {}  => {:.2}x comm speedup",
        fmt_time(sim_cxl),
        fmt_time(sim_ib),
        comm_speedup
    );
    println!(
        "end-to-end at the paper's 65/35 compute/comm mix: {:.2}x (paper: 1.11x)",
        e2e_paper_mix
    );
    println!("(this host's PJRT-CPU compute for reference: {})", fmt_time(compute));
    println!(
        "interconnect cost: IB switch ${:.0} vs CXL switch ${:.0} => {:.2}x cheaper (paper: 2.75x)",
        cost::infiniband_fabric(trainer.nranks()).switch_only(),
        cost::cxl_fabric(trainer.nranks(), cfg.ndevices, false).switch_only(),
        cost::switch_cost_ratio(),
    );
    Ok(())
}
