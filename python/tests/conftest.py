"""Test harness setup.

1. Put ``python/`` on ``sys.path`` so ``from compile import ...`` works when
   the suite is invoked as ``python -m pytest python/tests`` from the repo
   root (the tier-1 / CI invocation).
2. Offline fallback for ``hypothesis``: the build environment has no package
   registry, so when hypothesis is missing we install a minimal stub that
   runs each property test on a deterministic sample of draws. The real
   hypothesis is used whenever it is importable.
"""

import os
import random
import sys
import types

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))

try:
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover - exercised only in offline builds

    class _IntStrategy:
        def __init__(self, min_value, max_value):
            self.min_value = min_value
            self.max_value = max_value

        def draw(self, rng):
            return rng.randint(self.min_value, self.max_value)

    def _integers(min_value=0, max_value=1 << 31):
        return _IntStrategy(min_value, max_value)

    def _given(**strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                rng = random.Random(0xCC1)
                examples = getattr(wrapper, "_stub_max_examples", 10)
                for _ in range(examples):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco

    def _settings(max_examples=10, deadline=None, **_ignored):
        # `@settings` sits above `@given`, so it receives given's wrapper
        # and annotates it with the example budget the wrapper reads.
        def deco(fn):
            fn._stub_max_examples = max_examples
            return fn

        return deco

    stub = types.ModuleType("hypothesis")
    stub.given = _given
    stub.settings = _settings
    strategies = types.ModuleType("hypothesis.strategies")
    strategies.integers = _integers
    stub.strategies = strategies
    sys.modules["hypothesis"] = stub
    sys.modules["hypothesis.strategies"] = strategies
