"""AOT pipeline tests: artifacts exist, parse as HLO text, and the manifest
is consistent with the model presets."""

import os

import pytest

from compile import aot
from compile import model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def manifest():
    path = os.path.join(ART, "manifest.txt")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    out = {}
    with open(path) as f:
        for line in f:
            if "=" in line:
                k, v = line.strip().split("=", 1)
                out[k] = v
    return out


class TestManifest:
    def test_format_and_tiles(self):
        m = manifest()
        assert m["format"] == "hlo-text"
        tiles = [int(t) for t in m["reduce_tiles"].split(",")]
        assert tiles == list(aot.REDUCE_TILES)

    def test_artifact_files_exist_and_are_hlo(self):
        m = manifest()
        for k, v in m.items():
            if not v.endswith(".hlo.txt"):
                continue
            path = os.path.join(ART, v)
            assert os.path.exists(path), f"{k} -> missing {v}"
            with open(path) as f:
                head = f.read(4096)
            assert "HloModule" in head, f"{v} is not HLO text"

    def test_param_counts_match_model(self):
        m = manifest()
        for preset in ["tiny", "e2e"]:
            if f"params_{preset}" not in m:
                continue
            assert int(m[f"params_{preset}"]) == M.param_count(M.preset(preset))
            nranks = int(m["nranks"])
            assert int(m[f"shard_{preset}"]) == aot.shard_len(
                M.param_count(M.preset(preset)), nranks
            )


class TestLowering:
    def test_reduce_add_entry_signature(self):
        txt = aot.lower_reduce_add(aot.REDUCE_TILES[0])
        assert "HloModule" in txt and "ENTRY" in txt
        assert f"f32[{aot.REDUCE_TILES[0]}]" in txt

    def test_shard_len_padding(self):
        assert aot.shard_len(10, 4) == 3
        assert aot.shard_len(12, 4) == 3
        assert aot.shard_len(13, 4) == 4
