"""L2 correctness: model shapes, gradient sanity (numeric check), optimizer
semantics, and the flat-parameter round trip the FSDP driver relies on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


CFG = M.preset("tiny")


def batch(seed=0):
    k = jax.random.PRNGKey(seed)
    xb = jax.random.randint(k, (CFG.batch, CFG.seq_len), 0, CFG.vocab)
    yb = jnp.roll(xb, -1, axis=1)
    return xb.astype(jnp.int32), yb.astype(jnp.int32)


class TestForward:
    def test_logit_shape(self):
        params = M.init_params(CFG)
        xb, _ = batch()
        logits = M.forward(params, xb, CFG)
        assert logits.shape == (CFG.batch, CFG.seq_len, CFG.vocab)

    def test_loss_finite_and_near_uniform_at_init(self):
        params = M.init_params(CFG)
        xb, yb = batch()
        loss = M.loss_fn(params, xb, yb, CFG)
        assert np.isfinite(float(loss))
        # Random init ≈ uniform predictive distribution -> loss ≈ ln(vocab).
        assert abs(float(loss) - np.log(CFG.vocab)) < 0.5

    def test_causality(self):
        # Changing a future token must not affect past logits.
        params = M.init_params(CFG)
        xb, _ = batch()
        l1 = M.forward(params, xb, CFG)
        xb2 = xb.at[:, -1].set((xb[:, -1] + 1) % CFG.vocab)
        l2 = M.forward(params, xb2, CFG)
        np.testing.assert_allclose(l1[:, :-1], l2[:, :-1], rtol=1e-5, atol=1e-5)


class TestTrainStep:
    def test_grad_shapes_and_numeric_check(self):
        flat, unravel = M.flat_init(CFG)
        step = M.make_train_step(CFG, unravel)
        xb, yb = batch()
        loss, g = jax.jit(step)(flat, xb, yb)
        assert g.shape == flat.shape
        assert np.isfinite(float(loss))
        # Directional numeric derivative along a random direction.
        k = jax.random.PRNGKey(7)
        d = jax.random.normal(k, flat.shape, jnp.float32)
        d = d / jnp.linalg.norm(d)
        eps = 1e-3
        f = lambda v: float(M.loss_fn(unravel(v), xb, yb, CFG))
        numeric = (f(flat + eps * d) - f(flat - eps * d)) / (2 * eps)
        analytic = float(jnp.dot(g, d))
        assert abs(numeric - analytic) < 5e-2 * max(1.0, abs(numeric)), (
            numeric,
            analytic,
        )

    def test_loss_decreases_under_sgd(self):
        flat, unravel = M.flat_init(CFG)
        step = jax.jit(M.make_train_step(CFG, unravel))
        xb, yb = batch()
        l0, g = step(flat, xb, yb)
        flat2 = flat - 0.5 * g
        l1, _ = step(flat2, xb, yb)
        assert float(l1) < float(l0)


class TestAdam:
    def test_moves_against_gradient(self):
        p = jnp.zeros((8,), jnp.float32)
        g = jnp.ones((8,), jnp.float32)
        m = jnp.zeros_like(p)
        v = jnp.zeros_like(p)
        p2, m2, v2 = M.adam_update(p, g, m, v, jnp.float32(1.0), lr=0.1)
        assert bool(jnp.all(p2 < p))
        assert bool(jnp.all(m2 > 0)) and bool(jnp.all(v2 > 0))

    def test_first_step_size_is_lr(self):
        # With bias correction, |Δp| on step 1 ≈ lr regardless of |g|.
        p = jnp.zeros((4,), jnp.float32)
        for scale in [0.01, 1.0, 100.0]:
            g = jnp.full((4,), scale, jnp.float32)
            p2, _, _ = M.adam_update(
                p, g, jnp.zeros_like(p), jnp.zeros_like(p), jnp.float32(1.0), lr=0.1
            )
            np.testing.assert_allclose(-p2, 0.1, rtol=1e-3)


class TestFlatRoundTrip:
    def test_unravel_inverts_ravel(self):
        flat, unravel = M.flat_init(CFG, seed=3)
        from jax.flatten_util import ravel_pytree

        again, _ = ravel_pytree(unravel(flat))
        np.testing.assert_array_equal(flat, again)

    def test_param_count_matches_manifest_formula(self):
        n = M.param_count(CFG)
        assert n == flat_len_expected(CFG)


def flat_len_expected(cfg):
    d, f, L, V, T = cfg.d_model, cfg.d_ff, cfg.n_layers, cfg.vocab, cfg.seq_len
    per_layer = 2 * d + d * 3 * d + d * d + 2 * d + d * f + f + f * d + d
    return V * d + T * d + L * per_layer + 2 * d


class TestPresets:
    def test_known_presets(self):
        assert M.preset("tiny").d_model == 64
        assert M.preset("e2e").n_layers == 6
        with pytest.raises(KeyError):
            M.preset("gigantic")

    def test_e2e_param_scale(self):
        # The end-to-end example trains a ~10M model (DESIGN.md records the
        # substitution for the paper's Llama-3-8B).
        n = M.param_count(M.preset("e2e"))
        assert 8e6 < n < 15e6
