"""L1 correctness: the Pallas kernels vs the pure-jnp oracle, swept over
shapes/dtypes with hypothesis. This is the CORE kernel correctness signal —
the same HLO these kernels lower to is what the rust runtime executes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import reduce as K
from compile.kernels import ref

ALIGN = K.SUBLANE * K.LANE  # 1024


def rand(shape, seed, dtype=jnp.float32):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
    return x.astype(dtype)


class TestPairwiseAdd:
    @settings(max_examples=20, deadline=None)
    @given(
        blocks=st.integers(min_value=1, max_value=96),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_matches_ref_across_lengths(self, blocks, seed):
        n = blocks * ALIGN
        a = rand((n,), seed)
        b = rand((n,), seed + 1)
        got = K.pairwise_add(a, b)
        np.testing.assert_allclose(got, ref.pairwise_add_ref(a, b), rtol=1e-6)

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31))
    def test_bfloat16(self, seed):
        n = 4 * ALIGN
        a = rand((n,), seed, jnp.bfloat16)
        b = rand((n,), seed + 1, jnp.bfloat16)
        got = K.pairwise_add(a, b)
        np.testing.assert_allclose(
            got.astype(jnp.float32),
            ref.pairwise_add_ref(a, b).astype(jnp.float32),
            rtol=2e-2,
        )

    def test_misaligned_length_rejected(self):
        a = jnp.ones((100,), jnp.float32)
        with pytest.raises(AssertionError):
            K.pairwise_add(a, a)

    def test_exact_tile_boundary(self):
        n = K.TILE_ELEMS  # exactly one grid tile
        a = jnp.full((n,), 2.0, jnp.float32)
        b = jnp.full((n,), 3.0, jnp.float32)
        assert bool(jnp.all(K.pairwise_add(a, b) == 5.0))

    def test_multi_tile_grid(self):
        n = 3 * K.TILE_ELEMS
        a = jnp.arange(n, dtype=jnp.float32)
        out = K.pairwise_add(a, -a)
        assert bool(jnp.all(out == 0.0))


class TestStackedSum:
    @settings(max_examples=20, deadline=None)
    @given(
        ranks=st.integers(min_value=1, max_value=12),
        blocks=st.integers(min_value=1, max_value=48),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_matches_ref(self, ranks, blocks, seed):
        x = rand((ranks, blocks * ALIGN), seed)
        np.testing.assert_allclose(
            K.stacked_sum(x), ref.stacked_sum_ref(x), rtol=1e-5, atol=1e-5
        )

    def test_single_contributor_is_identity(self):
        x = rand((1, 2 * ALIGN), 3)
        np.testing.assert_allclose(K.stacked_sum(x), x[0], rtol=1e-7)

    def test_gradient_broadcasts(self):
        # custom_vjp: d(sum_r x)/dx = broadcast of the cotangent.
        x = rand((3, ALIGN), 5)
        g = jax.grad(lambda v: jnp.sum(K.stacked_sum(v) ** 2))(x)
        expect = 2.0 * jnp.broadcast_to(ref.stacked_sum_ref(x), x.shape)
        np.testing.assert_allclose(g, expect, rtol=1e-5)

    def test_pad_to_alignment_is_sum_safe(self):
        v = jnp.arange(1000, dtype=jnp.float32)
        p = K.pad_to_alignment(v)
        assert p.shape[0] % ALIGN == 0
        assert float(jnp.sum(p)) == float(jnp.sum(v))

    def test_vmem_estimate_fits_tpu_budget(self):
        # Double-buffered tiles for 12 contributors must fit a ~16 MiB VMEM.
        assert K.vmem_bytes(r=12) < 16 * 1024 * 1024


class TestLoweredHlo:
    """The artifacts must lower to plain HLO (no Mosaic custom-calls) so the
    rust CPU PJRT client can execute them."""

    def test_reduce_add_lowers_to_plain_hlo(self):
        from compile import aot

        txt = aot.lower_reduce_add(2 * ALIGN)
        assert "ENTRY" in txt
        assert "custom-call" not in txt.lower() or "mosaic" not in txt.lower()

    def test_lowered_numerics_roundtrip(self):
        # Execute the lowered computation via jax itself as a sanity check
        # (the rust integration test does the same through PJRT).
        from compile import aot

        txt = aot.lower_reduce_add(ALIGN)
        assert txt.count("ENTRY") == 1
