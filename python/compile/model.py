"""L2 — the LLM-training case-study compute graph (paper §5.5).

A decoder-only transformer LM whose parameters live in ONE flat f32 vector,
so the rust FSDP driver can treat them exactly like PyTorch FSDP treats its
flat parameter: AllGather the shards before compute, ReduceScatter the flat
gradient after backward (both through CXL-CCL), and apply the optimizer on
the local shard only.

Everything here runs **once**, at `make artifacts` time: the train step and
the optimizer update are AOT-lowered to HLO text and executed from rust via
PJRT. Python is never on the training path.

The per-token losses are accumulated with the L1 Pallas kernel
(:func:`kernels.reduce.stacked_sum`), putting the kernel inside the lowered
training graph as well as on the rust reduce-engine path.
"""

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from .kernels import reduce as kreduce


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Transformer shape. Presets mirror the paper's case study scaled to
    this host (see DESIGN.md §Substitutions)."""

    vocab: int = 256  # byte-level tokenizer
    d_model: int = 64
    n_layers: int = 2
    n_heads: int = 2
    seq_len: int = 32
    batch: int = 4

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model


PRESETS = {
    # CI / pytest scale: sub-second artifacts.
    "tiny": ModelConfig(vocab=256, d_model=64, n_layers=2, n_heads=2, seq_len=32, batch=4),
    # The end-to-end example (examples/train_fsdp.rs): ~11M params.
    "e2e": ModelConfig(vocab=256, d_model=384, n_layers=6, n_heads=6, seq_len=128, batch=8),
    # GPT-2-small-ish scale (~86M); a few demonstration steps only on CPU.
    "100m": ModelConfig(vocab=8192, d_model=768, n_layers=12, n_heads=12, seq_len=128, batch=4),
}


def init_params(cfg: ModelConfig, seed: int = 0):
    """Initialize the parameter pytree (layers stacked for lax.scan)."""
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 8)
    d, f, L = cfg.d_model, cfg.d_ff, cfg.n_layers
    s = 0.02
    params = {
        "embed": s * jax.random.normal(ks[0], (cfg.vocab, d), jnp.float32),
        "pos": s * jax.random.normal(ks[1], (cfg.seq_len, d), jnp.float32),
        "layers": {
            # One leading L axis per tensor -> scan-friendly, keeps the
            # lowered HLO O(1) in depth.
            "ln1_g": jnp.ones((L, d), jnp.float32),
            "ln1_b": jnp.zeros((L, d), jnp.float32),
            "wqkv": s * jax.random.normal(ks[2], (L, d, 3 * d), jnp.float32),
            "wo": s * jax.random.normal(ks[3], (L, d, d), jnp.float32),
            "ln2_g": jnp.ones((L, d), jnp.float32),
            "ln2_b": jnp.zeros((L, d), jnp.float32),
            "w1": s * jax.random.normal(ks[4], (L, d, f), jnp.float32),
            "b1": jnp.zeros((L, f), jnp.float32),
            "w2": s * jax.random.normal(ks[5], (L, f, d), jnp.float32),
            "b2": jnp.zeros((L, d), jnp.float32),
        },
        "lnf_g": jnp.ones((d,), jnp.float32),
        "lnf_b": jnp.zeros((d,), jnp.float32),
    }
    return params


def flat_init(cfg: ModelConfig, seed: int = 0) -> Tuple[jax.Array, object]:
    """Flat parameter vector + the unflatten closure."""
    params = init_params(cfg, seed)
    flat, unravel = ravel_pytree(params)
    return flat, unravel


def param_count(cfg: ModelConfig) -> int:
    flat, _ = flat_init(cfg)
    return int(flat.shape[0])


def _layer_norm(x, g, b):
    m = jnp.mean(x, axis=-1, keepdims=True)
    v = jnp.var(x, axis=-1, keepdims=True)
    return (x - m) * jax.lax.rsqrt(v + 1e-5) * g + b


def _block(x, lp, cfg: ModelConfig):
    """One transformer block; lp holds this layer's tensors (no L axis)."""
    B, T, d = x.shape
    h = _layer_norm(x, lp["ln1_g"], lp["ln1_b"])
    qkv = h @ lp["wqkv"]  # (B, T, 3d)
    q, kk, v = jnp.split(qkv, 3, axis=-1)

    def heads(t):
        return t.reshape(B, T, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)

    q, kk, v = heads(q), heads(kk), heads(v)
    att = (q @ kk.transpose(0, 1, 3, 2)) / jnp.sqrt(float(cfg.d_head))
    mask = jnp.tril(jnp.ones((T, T), bool))
    att = jnp.where(mask, att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    o = (att @ v).transpose(0, 2, 1, 3).reshape(B, T, d)
    x = x + o @ lp["wo"]
    h = _layer_norm(x, lp["ln2_g"], lp["ln2_b"])
    x = x + (jax.nn.gelu(h @ lp["w1"] + lp["b1"])) @ lp["w2"] + lp["b2"]
    return x


def forward(params, tokens, cfg: ModelConfig):
    """Logits over the vocab: (B, T) i32 -> (B, T, vocab) f32."""
    x = params["embed"][tokens] + params["pos"][None, : tokens.shape[1]]

    def step(carry, lp):
        return _block(carry, lp, cfg), None

    x, _ = jax.lax.scan(step, x, params["layers"])
    x = _layer_norm(x, params["lnf_g"], params["lnf_b"])
    # Tied output embedding (GPT-2 style).
    return x @ params["embed"].T


def loss_fn(params, xb, yb, cfg: ModelConfig):
    """Mean next-token NLL. The per-token losses are summed by the L1
    Pallas kernel (stacked_sum over a single-contributor stack), so the
    kernel is part of the lowered training graph."""
    logits = forward(params, xb, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, yb[..., None], axis=-1)[..., 0]  # (B,T)
    per_token = kreduce.pad_to_alignment(nll.reshape(-1))
    total = kreduce.stacked_sum(per_token[None, :])  # Pallas reduction
    return jnp.sum(total) / (cfg.batch * cfg.seq_len)


def make_train_step(cfg: ModelConfig, unravel):
    """(flat_params, xb, yb) -> (loss, flat_grads) — the artifact rust runs
    between AllGather and ReduceScatter."""

    def train_step(flat, xb, yb):
        def f(flat_v):
            return loss_fn(unravel(flat_v), xb, yb, cfg)

        loss, g = jax.value_and_grad(f)(flat)
        return (loss, g)

    return train_step


def adam_update(shard, grad, m, v, step, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    """Adam on a parameter shard — the post-ReduceScatter local update.

    `step` is the 1-based step count as f32 (bias correction).
    Returns (new_shard, new_m, new_v).
    """
    m = b1 * m + (1.0 - b1) * grad
    v = b2 * v + (1.0 - b2) * grad * grad
    mhat = m / (1.0 - b1**step)
    vhat = v / (1.0 - b2**step)
    return (shard - lr * mhat / (jnp.sqrt(vhat) + eps), m, v)


@functools.lru_cache(maxsize=None)
def preset(name: str) -> ModelConfig:
    if name not in PRESETS:
        raise KeyError(f"unknown preset {name!r}; have {sorted(PRESETS)}")
    return PRESETS[name]
