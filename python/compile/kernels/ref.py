"""Pure-jnp oracles for the Pallas kernels — the build-time correctness
signal (pytest asserts kernel == ref to float tolerance)."""

import jax
import jax.numpy as jnp


def pairwise_add_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return a + b


def stacked_sum_ref(x: jax.Array) -> jax.Array:
    return jnp.sum(x, axis=0)
