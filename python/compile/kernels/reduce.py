"""L1 — the CXL-CCL compute hot-spot as a Pallas kernel.

The consumer side of AllReduce / Reduce / ReduceScatter reads READY chunks
from the pool and accumulates them (paper Listing 3, line 14). On the GPU the
paper does this with CUDA kernels over chunk buffers; here the same hot-spot
is re-thought for a TPU-shaped memory hierarchy (DESIGN.md
§Hardware-Adaptation):

- the reduction is a grid over chunk *tiles*; BlockSpec stages each tile
  HBM→VMEM the way the doorbell/chunk schedule stages CXL→GPU,
- tiles are (8, 128)-aligned so the elementwise sum maps onto the VPU lanes
  (the reduction is bandwidth-bound — no MXU needed),
- `interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
  custom-calls; real-TPU numbers are estimated analytically (EXPERIMENTS.md
  §Perf-L1).

Two entry points:

- :func:`pairwise_add` — ``out = a + b`` over a fixed tile; exported
  standalone (``artifacts/reduce_add_*.hlo.txt``) and executed from the rust
  reduce engine through PJRT on the L3 hot path.
- :func:`stacked_sum` — ``(R, C) -> (C,)`` reduction over the rank axis;
  used by the L2 model for loss/grad-norm accumulation and as the
  many-contributor reduction oracle workload.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VPU-friendly tile: 8 sublanes x 128 lanes x 256 rows = 262144 f32 = 1 MiB
# of VMEM per operand tile — 3 operands double-buffered is 6 MiB, inside a
# TensorCore's ~16 MiB VMEM. One grid step per exported tile keeps the
# lowered HLO loop-free (§Perf: the grid loop dominated CPU-PJRT dispatch).
LANE = 128
SUBLANE = 8
TILE_ROWS = 256
TILE_ELEMS = TILE_ROWS * SUBLANE * LANE  # 262144


def _pick_block_rows(rows: int) -> int:
    """Largest sublane-multiple divisor of `rows` up to the tile budget, so
    the grid covers the array exactly (rows is always a multiple of SUBLANE
    because inputs are (8,128)-aligned)."""
    assert rows % SUBLANE == 0, rows
    cap = min(rows, TILE_ROWS * SUBLANE)
    br = cap - (cap % SUBLANE)
    while br > SUBLANE and rows % br != 0:
        br -= SUBLANE
    return max(br, SUBLANE)


def _add_kernel(a_ref, b_ref, o_ref):
    """One grid step: elementwise sum of a VMEM-resident tile."""
    o_ref[...] = a_ref[...] + b_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def pairwise_add(a: jax.Array, b: jax.Array, interpret: bool = True) -> jax.Array:
    """``a + b`` for 1-D f32 arrays whose length divides TILE_ELEMS' grid.

    The caller (aot.py / tests) pads to a multiple of ``SUBLANE * LANE``;
    the grid walks ``TILE_ELEMS``-sized tiles.
    """
    assert a.shape == b.shape and a.ndim == 1, (a.shape, b.shape)
    n = a.shape[0]
    assert n % (SUBLANE * LANE) == 0, f"length {n} not (8,128)-aligned"
    rows = n // LANE
    a2 = a.reshape(rows, LANE)
    b2 = b.reshape(rows, LANE)
    block_rows = _pick_block_rows(rows)
    grid = (rows // block_rows,)
    out = pl.pallas_call(
        _add_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANE), a.dtype),
        interpret=interpret,
    )(a2, b2)
    return out.reshape(n)


def _stacked_kernel(x_ref, o_ref):
    """One grid step: sum an (R, rows, LANE) VMEM block over axis 0."""
    o_ref[...] = jnp.sum(x_ref[...], axis=0)


@jax.custom_vjp
def stacked_sum(x: jax.Array) -> jax.Array:
    """Reduce ``(R, C) -> (C,)`` over the contributor axis R.

    R is the number of ranks contributing a chunk (2-16 in practice);
    C must be (8,128)-aligned. Each grid step stages an ``(R, block, 128)``
    brick through VMEM — the BlockSpec expresses the same
    producer-follows-consumer schedule the doorbell chunks give the CXL
    path.

    Reverse-mode: d(sum over R)/dx broadcasts the cotangent over R
    (``custom_vjp`` — pallas_call has no built-in autodiff rule).
    """
    return _stacked_sum_impl(x, True)


def _stacked_sum_fwd(x):
    return stacked_sum(x), x.shape[0]


def _stacked_sum_bwd(r, ct):
    return (jnp.broadcast_to(ct[None, :], (r, ct.shape[0])),)


stacked_sum.defvjp(_stacked_sum_fwd, _stacked_sum_bwd)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _stacked_sum_impl(x: jax.Array, interpret: bool = True) -> jax.Array:
    assert x.ndim == 2, x.shape
    r, n = x.shape
    assert n % (SUBLANE * LANE) == 0, f"length {n} not (8,128)-aligned"
    rows = n // LANE
    x3 = x.reshape(r, rows, LANE)
    # Many-contributor stacks shrink the block so (r+1) tiles still fit the
    # VMEM budget double-buffered (see vmem_bytes).
    block_rows = min(_pick_block_rows(rows), _rows_budget(r))
    while rows % block_rows != 0:
        block_rows -= SUBLANE
    grid = (rows // block_rows,)
    out = pl.pallas_call(
        _stacked_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((r, block_rows, LANE), lambda i: (0, i, 0))],
        out_specs=pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANE), x.dtype),
        interpret=interpret,
    )(x3)
    return out.reshape(n)


def pad_to_alignment(v: jax.Array) -> jax.Array:
    """Zero-pad a 1-D array up to (8,128) alignment (sum-safe padding)."""
    n = v.shape[0]
    unit = SUBLANE * LANE
    pad = (-n) % unit
    if pad:
        v = jnp.concatenate([v, jnp.zeros((pad,), v.dtype)])
    return v


VMEM_BUDGET = 12 << 20  # leave headroom below a TensorCore's ~16 MiB


def _rows_budget(r: int, dtype_bytes: int = 4) -> int:
    """Largest sublane-multiple block height such that (r+1) operand tiles
    fit the VMEM budget double-buffered."""
    rows = VMEM_BUDGET // (2 * (r + 1) * LANE * dtype_bytes)
    return max(SUBLANE, rows - rows % SUBLANE)


def vmem_bytes(r: int = 2, dtype_bytes: int = 4) -> int:
    """Static VMEM footprint estimate for one grid step (used by the
    roofline discussion in EXPERIMENTS.md §Perf): r input tiles + 1 output
    tile, double-buffered, with the r-aware block cap applied."""
    rows = min(TILE_ROWS * SUBLANE, _rows_budget(r, dtype_bytes))
    return 2 * (r + 1) * rows * LANE * dtype_bytes
