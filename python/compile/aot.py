"""AOT pipeline: lower the L1 kernel + L2 model to HLO **text** artifacts
the rust runtime loads via PJRT.

HLO text (not `.serialize()`): jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version the `xla` crate
binds) rejects; the text parser reassigns ids and round-trips cleanly.
See /opt/xla-example/README.md.

Usage (from `make artifacts`):
    cd python && python -m compile.aot --out-dir ../artifacts \
        --presets tiny,e2e --nranks 4

Emits:
    reduce_add_<tile>.hlo.txt       pairwise f32 add (rust reduce engine)
    model_step_<preset>.hlo.txt     (flat, xb, yb) -> (loss, flat_grads)
    adam_update_<preset>.hlo.txt    shard optimizer update
    manifest.txt                    key=value metadata the rust side parses
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import reduce as kreduce

# Tile sizes exported for the rust reduce engine (elements).
REDUCE_TILES = (32768, 262144)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    rust side unwraps a single tuple result)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_reduce_add(tile: int) -> str:
    spec = jax.ShapeDtypeStruct((tile,), jnp.float32)
    fn = lambda a, b: (kreduce.pairwise_add(a, b),)
    return to_hlo_text(jax.jit(fn).lower(spec, spec))


def shard_len(nparams: int, nranks: int) -> int:
    """FSDP pads the flat parameter to a multiple of nranks."""
    return (nparams + nranks - 1) // nranks


def lower_model(preset: str, nranks: int, out_dir: str):
    cfg = M.preset(preset)
    flat, unravel = M.flat_init(cfg)
    n = int(flat.shape[0])
    # Initial parameters (jax init) for the rust trainer, f32 little-endian.
    import numpy as np

    pbin = os.path.join(out_dir, f"params_{preset}.bin")
    np.asarray(flat, dtype="<f4").tofile(pbin)
    step = M.make_train_step(cfg, unravel)
    tok = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32)
    pspec = jax.ShapeDtypeStruct((n,), jnp.float32)
    step_txt = to_hlo_text(jax.jit(step).lower(pspec, tok, tok))

    sl = shard_len(n, nranks)
    sspec = jax.ShapeDtypeStruct((sl,), jnp.float32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)
    upd = lambda p, g, m, v, t: M.adam_update(p, g, m, v, t)
    upd_txt = to_hlo_text(jax.jit(upd).lower(sspec, sspec, sspec, sspec, scalar))
    return cfg, n, sl, step_txt, upd_txt


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--presets", default="tiny,e2e")
    ap.add_argument("--nranks", type=int, default=4)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = [
        "format=hlo-text",
        f"nranks={args.nranks}",
        f"reduce_tiles={','.join(str(t) for t in REDUCE_TILES)}",
    ]

    for tile in REDUCE_TILES:
        path = os.path.join(args.out_dir, f"reduce_add_{tile}.hlo.txt")
        txt = lower_reduce_add(tile)
        with open(path, "w") as f:
            f.write(txt)
        manifest.append(f"reduce_add_{tile}=reduce_add_{tile}.hlo.txt")
        print(f"wrote {path} ({len(txt)} chars)")

    for preset in [p for p in args.presets.split(",") if p]:
        cfg, n, sl, step_txt, upd_txt = lower_model(preset, args.nranks, args.out_dir)
        sp = os.path.join(args.out_dir, f"model_step_{preset}.hlo.txt")
        up = os.path.join(args.out_dir, f"adam_update_{preset}.hlo.txt")
        with open(sp, "w") as f:
            f.write(step_txt)
        with open(up, "w") as f:
            f.write(upd_txt)
        manifest += [
            f"model_step_{preset}=model_step_{preset}.hlo.txt",
            f"adam_update_{preset}=adam_update_{preset}.hlo.txt",
            f"params_bin_{preset}=params_{preset}.bin",
            f"params_{preset}={n}",
            f"shard_{preset}={sl}",
            f"vocab_{preset}={cfg.vocab}",
            f"d_model_{preset}={cfg.d_model}",
            f"n_layers_{preset}={cfg.n_layers}",
            f"seq_len_{preset}={cfg.seq_len}",
            f"batch_{preset}={cfg.batch}",
        ]
        print(f"wrote {sp} ({len(step_txt)} chars), {up} ({len(upd_txt)} chars); "
              f"params={n} shard={sl}")

    mpath = os.path.join(args.out_dir, "manifest.txt")
    with open(mpath, "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
